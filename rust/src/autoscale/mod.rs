//! KEDA-style autoscaler with proportional quota allocation.
//!
//! Implements the paper's scaling rule (§3.5): on every poll, read each
//! pool's queue backlog from the metrics pipeline and compute the desired
//! replica count such that
//!
//!   * a pool with zero backlog scales to zero (KEDA, not plain HPA);
//!   * when the aggregate demand fits the cluster quota, every pool gets
//!     one replica per queued task (target 1 task/replica);
//!   * when it does not fit, *"the available resources of the cluster are
//!     allocated proportionally to the current workloads of each worker
//!     pool"* — CPU shares proportional to backlog × per-replica request,
//!     with largest-remainder rounding so no capacity is stranded.
//!
//! Scale-up applies immediately; scale-down goes through a stabilization
//! window (HPA semantics) so transient dips don't thrash the pools.
//!
//! Pools are identified by dense index, aligned with the driver's interned
//! [`crate::broker::PoolId`] space: `backlogs`/`current`/desired are plain
//! slices indexed by pool, so the per-tick reconciliation allocates no
//! string-keyed maps (EXPERIMENTS.md §Perf).

use crate::k8s::resources::Resources;
use crate::sim::SimTime;

/// Static description of one worker pool.
#[derive(Debug, Clone)]
pub struct PoolSpec {
    pub name: String,
    /// Pod template requests for this pool's workers.
    pub requests: Resources,
}

#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Metric poll interval (HPA default-ish: 15 s).
    pub poll_ms: u64,
    /// Scale-down stabilization window (default 30 s).
    pub stabilization_ms: u64,
    /// CPU quota the pools may collectively use (millicores). Typically
    /// the whole cluster, minus head-room for job-based tasks in the
    /// hybrid model.
    pub quota_cpu_m: u64,
    /// Queue tasks per replica the scaler targets (1.0 = one worker per
    /// queued task, the paper's configuration).
    pub target_backlog_per_replica: f64,
    /// Floor on replicas per pool. 0 = KEDA semantics (scale to zero, the
    /// paper's choice, §3.5); 1 = plain-HPA semantics ("scaling worker
    /// pools to zero ... was not possible using the standard HPA").
    pub min_replicas: usize,
    /// §5 future work: vertical pod autoscaling. After a pool has executed
    /// `vpa_min_samples` tasks, newly-created workers request the type's
    /// *observed* CPU usage instead of the user's over-provisioned request
    /// (right-sizing improves bin-packing).
    pub vpa: bool,
    /// Completed-task threshold before VPA trusts its usage estimate.
    pub vpa_min_samples: u64,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            poll_ms: 15_000,
            stabilization_ms: 30_000,
            quota_cpu_m: 68_000,
            target_backlog_per_replica: 1.0,
            min_replicas: 0,
            vpa: false,
            vpa_min_samples: 20,
        }
    }
}

/// The autoscaler.
#[derive(Debug)]
pub struct Autoscaler {
    pub cfg: AutoscalerConfig,
    pools: Vec<PoolSpec>,
    /// Last time each pool's desired count was >= its current count
    /// (drives the stabilization window); `None` until first polled.
    last_not_below: Vec<Option<SimTime>>,
    pub scale_events: u64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerConfig, pools: Vec<PoolSpec>) -> Self {
        let n = pools.len();
        Autoscaler {
            cfg,
            pools,
            last_not_below: vec![None; n],
            scale_events: 0,
        }
    }

    pub fn pools(&self) -> &[PoolSpec] {
        &self.pools
    }

    /// VPA hook: replace a pool's pod-template requests (right-sizing),
    /// so quota allocation budgets with the observed usage.
    pub fn set_pool_requests(&mut self, pool: usize, requests: Resources) {
        self.pools[pool].requests = requests;
    }

    /// Chaos hook: re-budget the CPU quota to the cluster's *surviving*
    /// capacity. When a spot node is reclaimed or crashes, the driver
    /// shrinks the quota so the pools stop requesting replicas the
    /// scheduler could never place (back-off storms on a shrunken
    /// cluster); when replacement capacity arrives, the quota is restored.
    pub fn set_quota(&mut self, quota_cpu_m: u64) {
        self.cfg.quota_cpu_m = quota_cpu_m;
    }

    /// Name-keyed variant of [`Autoscaler::set_pool_requests`] (cold path).
    pub fn update_pool_requests(&mut self, name: &str, requests: Resources) {
        if let Some(p) = self.pools.iter_mut().find(|p| p.name == name) {
            p.requests = requests;
        }
    }

    /// Pure allocation rule: backlog per pool -> desired replicas, under
    /// the CPU quota, proportional when contended. `backlogs` is indexed
    /// by pool; `desired` is cleared and refilled to the same length.
    pub fn allocate_into(&self, backlogs: &[usize], desired: &mut Vec<usize>) {
        debug_assert_eq!(backlogs.len(), self.pools.len());
        desired.clear();
        // raw demand: one replica per `target_backlog_per_replica` tasks
        let mut demand_cpu: f64 = 0.0;
        let mut raw: Vec<f64> = Vec::with_capacity(self.pools.len());
        for (i, p) in self.pools.iter().enumerate() {
            let backlog = backlogs[i] as f64;
            let replicas = (backlog / self.cfg.target_backlog_per_replica)
                .ceil()
                .max(self.cfg.min_replicas as f64);
            raw.push(replicas);
            demand_cpu += replicas * p.requests.cpu_m as f64;
        }
        let quota = self.cfg.quota_cpu_m as f64;
        if demand_cpu <= quota {
            desired.extend(raw.iter().map(|&r| r as usize));
            return;
        }
        // Contended: proportional CPU shares, largest-remainder rounding.
        let mut fracs: Vec<(usize, f64, f64)> = Vec::new(); // (pool, floor, frac)
        let mut used = 0.0;
        for (i, &replicas) in raw.iter().enumerate() {
            let p = &self.pools[i];
            let cpu_share = quota * (replicas * p.requests.cpu_m as f64) / demand_cpu;
            let ideal = cpu_share / p.requests.cpu_m as f64;
            // never allocate more than the raw demand
            let ideal = ideal.min(replicas);
            let fl = ideal.floor();
            used += fl * p.requests.cpu_m as f64;
            fracs.push((i, fl, ideal - fl));
        }
        let mut counts: Vec<f64> = fracs.iter().map(|&(_, fl, _)| fl).collect();
        // hand out remaining quota by largest fractional part (stable sort:
        // equal fractions resolve in pool-declaration order)
        fracs.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        for &(i, _, frac) in &fracs {
            if frac <= 0.0 {
                continue;
            }
            let c = self.pools[i].requests.cpu_m as f64;
            if used + c <= quota {
                used += c;
                counts[i] += 1.0;
            }
        }
        for (i, n) in counts.into_iter().enumerate() {
            // a pool with backlog always gets at least one replica if any
            // quota remains — otherwise short queues starve forever
            let n = if backlogs[i] > 0 { n.max(1.0) } else { n };
            let n = n.max(self.cfg.min_replicas as f64);
            desired.push(n as usize);
        }
    }

    /// Allocating convenience wrapper around [`Autoscaler::allocate_into`].
    pub fn allocate(&self, backlogs: &[usize]) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.pools.len());
        self.allocate_into(backlogs, &mut out);
        out
    }

    /// Stateful poll: applies the stabilization window to scale-downs.
    /// `current` is the present replica count per pool; `out` is cleared
    /// and refilled with the desired count per pool.
    pub fn poll_into(
        &mut self,
        now: SimTime,
        backlogs: &[usize],
        current: &[usize],
        out: &mut Vec<usize>,
    ) {
        debug_assert_eq!(current.len(), self.pools.len());
        self.allocate_into(backlogs, out);
        for i in 0..self.pools.len() {
            let want = out[i];
            let cur = current[i];
            let entry = self.last_not_below[i].get_or_insert(now);
            if want >= cur {
                *entry = now;
                if want != cur {
                    self.scale_events += 1;
                }
            } else {
                // scale-down only after the stabilization window
                let since = now.saturating_sub(*entry);
                if since.as_millis() >= self.cfg.stabilization_ms {
                    self.scale_events += 1;
                } else {
                    out[i] = cur;
                }
            }
        }
    }

    /// Allocating convenience wrapper around [`Autoscaler::poll_into`].
    pub fn poll(&mut self, now: SimTime, backlogs: &[usize], current: &[usize]) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.pools.len());
        self.poll_into(now, backlogs, current, &mut out);
        out
    }
}

/// Split an integer `total` proportionally to `weights` with
/// largest-remainder rounding: the shares sum to exactly `total` whenever
/// any weight is positive, no share deviates from its exact proportion by
/// more than one unit, and zero-weight lanes get exactly zero. All-zero
/// weights yield all-zero shares — the caller decides what an unweighted
/// split means. Equal remainders resolve in index order, so the split is
/// deterministic. Used for per-tenant sub-quota carving and the node-pool
/// partitioning in [`crate::k8s::isolation::IsolationState::set_tenants`].
pub fn split_quota(total: u64, weights: &[u64]) -> Vec<u64> {
    let wsum: u64 = weights.iter().sum();
    if wsum == 0 {
        return vec![0; weights.len()];
    }
    let mut shares: Vec<u64> = Vec::with_capacity(weights.len());
    let mut rems: Vec<(u128, usize)> = Vec::with_capacity(weights.len());
    let mut given = 0u64;
    for (i, &w) in weights.iter().enumerate() {
        let exact = total as u128 * w as u128;
        let fl = (exact / wsum as u128) as u64;
        shares.push(fl);
        given += fl;
        rems.push((exact % wsum as u128, i));
    }
    // hand the remainder out by largest fractional part; a unit of
    // remainder only ever lands on a lane with a nonzero fraction
    rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    let mut left = total - given;
    for &(_, i) in &rems {
        if left == 0 {
            break;
        }
        shares[i] += 1;
        left -= 1;
    }
    shares
}

#[cfg(test)]
mod tests {
    use super::*;

    // pool 0 = mProject (1000m), pool 1 = mDiffFit (500m)
    fn pools() -> Vec<PoolSpec> {
        vec![
            PoolSpec {
                name: "mProject".into(),
                requests: Resources::new(1000, 1024),
            },
            PoolSpec {
                name: "mDiffFit".into(),
                requests: Resources::new(500, 512),
            },
        ]
    }

    #[test]
    fn uncontended_gives_one_replica_per_task() {
        let a = Autoscaler::new(
            AutoscalerConfig {
                quota_cpu_m: 68_000,
                ..Default::default()
            },
            pools(),
        );
        let d = a.allocate(&[10, 20]);
        assert_eq!(d, vec![10, 20]);
    }

    #[test]
    fn zero_backlog_scales_to_zero() {
        let a = Autoscaler::new(AutoscalerConfig::default(), pools());
        assert_eq!(a.allocate(&[0, 0]), vec![0, 0]);
    }

    #[test]
    fn plain_hpa_min_replicas_floor() {
        // §3.5: standard HPA cannot scale to zero — min_replicas = 1
        let a = Autoscaler::new(
            AutoscalerConfig {
                min_replicas: 1,
                ..Default::default()
            },
            pools(),
        );
        assert_eq!(a.allocate(&[0, 0]), vec![1, 1]);
        // floor also survives the contended path
        let a2 = Autoscaler::new(
            AutoscalerConfig {
                min_replicas: 1,
                quota_cpu_m: 1_000,
                ..Default::default()
            },
            pools(),
        );
        let d2 = a2.allocate(&[100, 0]);
        assert!(d2[1] >= 1);
    }

    #[test]
    fn contended_allocation_is_proportional() {
        // quota 10 cores; demands: mProject 100*1000m, mDiffFit 100*500m
        // => shares 2/3 vs 1/3 of cpu: ~6.6 cores vs ~3.3 cores
        // => ~6 mProject replicas, ~6 mDiffFit replicas
        let a = Autoscaler::new(
            AutoscalerConfig {
                quota_cpu_m: 10_000,
                ..Default::default()
            },
            pools(),
        );
        let d = a.allocate(&[100, 100]);
        let cpu = d[0] * 1000 + d[1] * 500;
        assert!(cpu <= 10_000, "quota violated: {cpu}");
        assert!(cpu >= 9_000, "quota wasted: {cpu}");
        // proportional: mProject gets ~2x the cpu of mDiffFit
        let ratio = (d[0] as f64 * 1000.0) / (d[1] as f64 * 500.0);
        assert!((1.5..3.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn contended_pool_with_backlog_gets_at_least_one() {
        let mut ps = pools();
        ps.push(PoolSpec {
            name: "mBackground".into(),
            requests: Resources::new(500, 512),
        });
        let a = Autoscaler::new(
            AutoscalerConfig {
                quota_cpu_m: 4_000,
                ..Default::default()
            },
            ps,
        );
        let d = a.allocate(&[1000, 1000, 1]);
        assert!(d[2] >= 1);
    }

    #[test]
    fn never_exceeds_raw_demand() {
        let a = Autoscaler::new(
            AutoscalerConfig {
                quota_cpu_m: 1_000_000,
                ..Default::default()
            },
            pools(),
        );
        assert_eq!(a.allocate(&[3, 0]), vec![3, 0]);
    }

    #[test]
    fn scale_up_is_immediate() {
        let mut a = Autoscaler::new(AutoscalerConfig::default(), pools());
        let d = a.poll(SimTime(0), &[5, 0], &[0, 0]);
        assert_eq!(d[0], 5);
    }

    #[test]
    fn scale_down_waits_for_stabilization() {
        let mut a = Autoscaler::new(
            AutoscalerConfig {
                stabilization_ms: 30_000,
                ..Default::default()
            },
            pools(),
        );
        let cur = [10, 0];
        // backlog dropped to zero at t=0: hold replicas
        let d = a.poll(SimTime(0), &[0, 0], &cur);
        assert_eq!(d[0], 10);
        // still inside window at t=15s
        let d = a.poll(SimTime(15_000), &[0, 0], &cur);
        assert_eq!(d[0], 10);
        // window elapsed at t=30s: scale to zero
        let d = a.poll(SimTime(30_000), &[0, 0], &cur);
        assert_eq!(d[0], 0);
    }

    #[test]
    fn recovery_resets_stabilization() {
        let mut a = Autoscaler::new(
            AutoscalerConfig {
                stabilization_ms: 30_000,
                ..Default::default()
            },
            pools(),
        );
        let cur = [10, 0];
        a.poll(SimTime(0), &[0, 0], &cur);
        // backlog returns at t=15s -> desired >= current resets the window
        let d = a.poll(SimTime(15_000), &[10, 0], &cur);
        assert_eq!(d[0], 10);
        // drops again; need 30 more seconds from t=15s... at t=40s: not yet
        let d = a.poll(SimTime(40_000), &[0, 0], &cur);
        assert_eq!(d[0], 10);
        let d = a.poll(SimTime(45_000), &[0, 0], &cur);
        assert_eq!(d[0], 0);
    }

    #[test]
    fn poll_into_reuses_buffer() {
        let mut a = Autoscaler::new(AutoscalerConfig::default(), pools());
        let mut buf = vec![123, 456, 789]; // stale content is discarded
        a.poll_into(SimTime(0), &[4, 2], &[0, 0], &mut buf);
        assert_eq!(buf, vec![4, 2]);
    }

    #[test]
    fn vpa_request_update_changes_allocation() {
        let mut a = Autoscaler::new(
            AutoscalerConfig {
                quota_cpu_m: 2_000,
                ..Default::default()
            },
            pools(),
        );
        let before = a.allocate(&[0, 100]);
        a.set_pool_requests(1, Resources::new(250, 512));
        let after = a.allocate(&[0, 100]);
        assert!(after[1] > before[1], "{after:?} vs {before:?}");
        // name-keyed variant hits the same pool
        a.update_pool_requests("mDiffFit", Resources::new(500, 512));
        assert_eq!(a.allocate(&[0, 100]), before);
    }

    #[test]
    fn quota_shrinks_and_restores_with_node_churn() {
        // chaos: a reclaimed node takes its share of quota with it
        let mut a = Autoscaler::new(
            AutoscalerConfig {
                quota_cpu_m: 8_000,
                ..Default::default()
            },
            pools(),
        );
        let healthy = a.allocate(&[100, 0]);
        a.set_quota(4_000); // half the cluster reclaimed
        let degraded = a.allocate(&[100, 0]);
        assert!(
            degraded[0] < healthy[0],
            "degraded {degraded:?} vs healthy {healthy:?}"
        );
        assert!(degraded[0] * 1000 <= 4_000 + 1000, "respects the new quota");
        a.set_quota(8_000); // replacement capacity arrived
        assert_eq!(a.allocate(&[100, 0]), healthy);
    }

    #[test]
    fn quota_shrink_below_current_deployment_scales_down() {
        // shrink below what is already deployed: desired drops to fit the
        // new quota, but only after the stabilization window (no thrash)
        let mut a = Autoscaler::new(
            AutoscalerConfig {
                quota_cpu_m: 8_000,
                stabilization_ms: 30_000,
                ..Default::default()
            },
            pools(),
        );
        let cur = [8, 0]; // 8000m of mProject already deployed
        a.set_quota(2_000);
        let d = a.poll(SimTime(0), &[100, 0], &cur);
        assert_eq!(d[0], 8, "held during stabilization");
        let d = a.poll(SimTime(30_000), &[100, 0], &cur);
        assert_eq!(d[0], 2, "drained to the shrunken quota");
    }

    #[test]
    fn zero_quota_drains_backlogged_pools_to_the_floor() {
        // a fully-reclaimed cluster (quota 0) must not panic or divide by
        // zero: backlogged pools keep the one keep-alive replica the
        // starvation floor guarantees, idle pools drain to zero
        let mut a = Autoscaler::new(
            AutoscalerConfig {
                quota_cpu_m: 8_000,
                stabilization_ms: 0,
                ..Default::default()
            },
            pools(),
        );
        a.set_quota(0);
        assert_eq!(a.allocate(&[100, 0]), vec![1, 0]);
        // with no backlog at all, the pools drain completely
        assert_eq!(a.poll(SimTime(0), &[0, 0], &[8, 4]), vec![0, 0]);
    }

    #[test]
    fn split_quota_is_exact_and_deterministic() {
        assert_eq!(split_quota(8, &[3, 1]), vec![6, 2]);
        // equal remainders resolve in index order
        assert_eq!(split_quota(10, &[1, 1, 1]), vec![4, 3, 3]);
        assert_eq!(split_quota(0, &[5, 5]), vec![0, 0]);
        assert_eq!(split_quota(7, &[0, 0]), vec![0, 0], "all-zero weights");
        assert_eq!(split_quota(5, &[]), Vec::<u64>::new());
    }

    #[test]
    fn split_quota_never_exceeds_aggregate_property() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(13);
        for _ in 0..200 {
            let n = 1 + rng.below(6) as usize;
            let w: Vec<u64> = (0..n).map(|_| rng.below(10)).collect();
            let total = rng.below(100);
            let s = split_quota(total, &w);
            let sum: u64 = s.iter().sum();
            let wsum: u64 = w.iter().sum();
            if wsum == 0 {
                assert_eq!(sum, 0);
                continue;
            }
            // the per-tenant sub-quota split exactly covers — and never
            // exceeds — the aggregate quota
            assert_eq!(sum, total, "weights {w:?} total {total}");
            for (i, &share) in s.iter().enumerate() {
                let exact = total as f64 * w[i] as f64 / wsum as f64;
                assert!(
                    (share as f64 - exact).abs() <= 1.0,
                    "share {share} vs exact {exact}"
                );
                if w[i] == 0 {
                    assert_eq!(share, 0, "zero weight must get zero share");
                }
            }
        }
    }

    #[test]
    fn quota_respected_under_many_pools_property() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n_pools = 2 + rng.below(5) as usize;
            let ps: Vec<PoolSpec> = (0..n_pools)
                .map(|i| PoolSpec {
                    name: format!("p{i}"),
                    requests: Resources::new(250 + rng.below(8) * 250, 512),
                })
                .collect();
            let quota = 4_000 + rng.below(64) * 1_000;
            let a = Autoscaler::new(
                AutoscalerConfig {
                    quota_cpu_m: quota,
                    ..Default::default()
                },
                ps.clone(),
            );
            let b: Vec<usize> = (0..n_pools).map(|_| rng.below(2000) as usize).collect();
            let d = a.allocate(&b);
            let used: u64 = ps
                .iter()
                .enumerate()
                .map(|(i, p)| d[i] as u64 * p.requests.cpu_m)
                .sum();
            let demand: u64 = ps
                .iter()
                .enumerate()
                .map(|(i, p)| b[i] as u64 * p.requests.cpu_m)
                .sum();
            if demand > quota {
                // at most one extra minimum replica per pool beyond quota
                let slack: u64 = ps.iter().map(|p| p.requests.cpu_m).sum();
                assert!(used <= quota + slack, "used {used} quota {quota}");
            } else {
                assert!(used <= demand);
            }
        }
    }
}
