//! The Kubernetes scheduler model: bin-packing by resource requests, a
//! pending queue, and per-pod exponential back-off for unschedulable pods.
//!
//! This component produces the paper's central job-model pathology (§4.2):
//! when far more pods are requested than the cluster fits, unschedulable
//! pods are retried "with increasingly longer exponential back-off delay
//! (up to several minutes)". Even after resources free up, pods sleep out
//! their back-off, leaving the cluster idle (the ~100 s gap in Fig. 4),
//! and then wake in synchronized batches.

use super::isolation::IsolationState;
use super::node::{Node, NodeId};
use super::pod::{Payload, PodId, PodPhase, PodTable};
use crate::sim::SimTime;
use std::collections::VecDeque;

/// Scheduler tuning; defaults follow kube-scheduler semantics scaled to the
/// paper's observations.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Time to process one pod through a scheduling cycle (filter/score/
    /// bind). Real kube-scheduler sustains ~100 pods/s => ~10 ms each.
    pub bind_ms: u64,
    /// Initial back-off after a failed scheduling attempt.
    pub backoff_initial_ms: u64,
    /// Back-off cap. The paper observed "up to several minutes".
    pub backoff_max_ms: u64,
    /// Multiplier per failed attempt.
    pub backoff_factor: f64,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            bind_ms: 10,
            backoff_initial_ms: 1_000,
            backoff_max_ms: 100_000, // the ~100 s gap scale observed in Fig. 4
            backoff_factor: 2.0,
        }
    }
}

/// Data-locality oracle for placement scoring: how many of the pod's
/// input bytes are already cached on a node. Implemented by
/// [`crate::data::DataPlane`]; the scheduler itself stays storage-agnostic.
/// Takes the pod's [`Payload`] rather than a whole pod row — the oracle
/// only ever inspects the task batch, and the SoA [`PodTable`] hands out
/// one column without materializing a `Pod`.
pub trait DataLocality {
    fn cached_input_bytes(&self, payload: &Payload, node: &Node) -> u64;
}

/// Why a pod failed a scheduling attempt (flight-recorder annotation on
/// every back-off; costs one enum push per miss, nothing on binds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackoffReason {
    /// The namespace ResourceQuota rejected admission.
    Quota,
    /// Capacity that would have fit exists but is cordoned (drain
    /// warning / blacklist) — churn-attributable back-off.
    Cordoned,
    /// No node fits the request.
    NoFit,
}

impl BackoffReason {
    pub fn name(self) -> &'static str {
        match self {
            BackoffReason::Quota => "quota",
            BackoffReason::Cordoned => "cordoned",
            BackoffReason::NoFit => "nofit",
        }
    }
}

/// Result of one scheduling pass.
#[derive(Debug, Default, PartialEq)]
pub struct SchedulePass {
    /// (pod, node, bind-completion time) for pods that were placed.
    pub bound: Vec<(PodId, NodeId, SimTime)>,
    /// Pods that failed to fit, with the time their back-off expires.
    pub backed_off: Vec<(PodId, SimTime)>,
    /// Why each entry of `backed_off` missed (parallel vector).
    pub backoff_reasons: Vec<BackoffReason>,
}

/// The scheduler: an active queue plus the back-off bookkeeping.
///
/// Pod membership flags are dense vectors indexed by PodId — set-based
/// bookkeeping (`BTreeSet` + `VecDeque::contains`) was ~13% of the 16k
/// job-model simulation (EXPERIMENTS.md §Perf).
#[derive(Debug)]
pub struct Scheduler {
    pub cfg: SchedulerConfig,
    /// Pods awaiting a scheduling attempt, FIFO.
    active: VecDeque<PodId>,
    /// Dense flag: pod is in the active queue.
    in_active: Vec<bool>,
    /// Dense flag: pod is sleeping in back-off (woken by `BackoffExpire`).
    sleeping: Vec<bool>,
    sleeping_count: usize,
    active_count: usize,
    /// Serialization of the scheduling pipeline: the next bind may not
    /// complete before this time (throughput model).
    busy_until: SimTime,
    // -- counters for reports/metrics -------------------------------------
    pub binds_total: u64,
    pub backoffs_total: u64,
    /// Attempts that failed *only* because the capacity that would have
    /// fit was cordoned (chaos: drain warnings / blacklisted nodes) — the
    /// back-off churn attributable to churn rather than to load.
    pub cordoned_misses: u64,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Self {
        Scheduler {
            cfg,
            active: VecDeque::new(),
            in_active: Vec::new(),
            sleeping: Vec::new(),
            sleeping_count: 0,
            active_count: 0,
            busy_until: SimTime::ZERO,
            binds_total: 0,
            backoffs_total: 0,
            cordoned_misses: 0,
        }
    }

    fn ensure(&mut self, pod: PodId) {
        let i = pod.0 as usize;
        if i >= self.in_active.len() {
            self.in_active.resize(i + 1, false);
            self.sleeping.resize(i + 1, false);
        }
    }

    /// Enqueue a newly-created (or woken-from-back-off) pod.
    pub fn enqueue(&mut self, pod: PodId) {
        self.ensure(pod);
        let i = pod.0 as usize;
        if self.sleeping[i] {
            self.sleeping[i] = false;
            self.sleeping_count -= 1;
        }
        if !self.in_active[i] {
            self.in_active[i] = true;
            self.active_count += 1;
            self.active.push_back(pod);
        }
    }

    pub fn queue_len(&self) -> usize {
        self.active_count
    }

    pub fn sleeping_len(&self) -> usize {
        self.sleeping_count
    }

    pub fn is_sleeping(&self, pod: PodId) -> bool {
        self.sleeping.get(pod.0 as usize).copied().unwrap_or(false)
    }

    /// Run scheduling attempts for every pod in the active queue against
    /// the current node state. Successfully placed pods get resources
    /// allocated immediately (bind) and a bind-completion timestamp spaced
    /// by `bind_ms` (throughput limit). Unschedulable pods go to sleep with
    /// exponential back-off.
    pub fn pass(&mut self, now: SimTime, pods: &mut PodTable, nodes: &mut [Node]) -> SchedulePass {
        let mut out = SchedulePass::default();
        self.pass_into(now, pods, nodes, &mut out, None, None);
        out
    }

    /// Allocation-free variant of [`Scheduler::pass`]: clears and refills
    /// `out`, so the driver can reuse one `SchedulePass` across the many
    /// passes a run performs (EXPERIMENTS.md §Perf). With a
    /// [`DataLocality`] oracle, fitting nodes are ranked by cached input
    /// bytes first (ties fall back to best-fit) — when no node caches
    /// anything, the choice is bit-identical to the oracle-free path.
    ///
    /// With an [`IsolationState`] oracle, two more gates apply per pod:
    /// the namespace ResourceQuota is checked *before* the node search
    /// (a full quota throttles the pod through the same exponential
    /// back-off — Kubernetes rejects at the apiserver and the owning
    /// controller retries), and node-pool placement constraints filter
    /// the candidate nodes like taints/tolerations. Quota is charged on
    /// bind and released with the pod. A `None` oracle (isolation off)
    /// is bit-identical to pre-isolation behavior.
    pub fn pass_into(
        &mut self,
        now: SimTime,
        pods: &mut PodTable,
        nodes: &mut [Node],
        out: &mut SchedulePass,
        locality: Option<&dyn DataLocality>,
        mut isolation: Option<&mut IsolationState>,
    ) {
        out.bound.clear();
        out.backed_off.clear();
        out.backoff_reasons.clear();
        // hoisted: on healthy (chaos-free) runs no node is ever cordoned,
        // so the per-miss attribution scan below is skipped entirely
        let any_cordoned = nodes.iter().any(|n| n.cordoned);
        let n_attempts = self.active.len();
        for _ in 0..n_attempts {
            let pid = match self.active.pop_front() {
                Some(p) => p,
                None => break,
            };
            if !self.in_active[pid.0 as usize] {
                continue; // forgotten while queued
            }
            self.in_active[pid.0 as usize] = false;
            self.active_count -= 1;
            let i = pid.0 as usize;
            if pods.phase[i] != PodPhase::Pending {
                continue; // deleted while queued
            }
            let req = pods.requests[i];
            // Namespace quota admission first: a throttled pod never
            // reaches the node search.
            let tenant = isolation.as_deref().map(|iso| iso.tenant_of_pod(pid));
            let admitted = match (isolation.as_deref_mut(), tenant) {
                (Some(iso), Some(t)) => {
                    if iso.admits(t, req) {
                        true
                    } else {
                        iso.stats.add_throttle(t);
                        false
                    }
                }
                _ => true,
            };
            // Filter + score: best-fit on CPU (tightest remaining capacity
            // that still fits) — keeps large pods schedulable longer than
            // spread-scoring would, matching kube-scheduler's default
            // bin-packing behaviour under pressure well enough. The
            // locality oracle prepends a cached-bytes rank; `min_by_key`
            // keeps the *first* minimum, so an all-zero score degenerates
            // to exactly the best-fit choice. The isolation oracle drops
            // nodes outside the tenant's pool from the candidate set.
            let fit = if !admitted {
                None
            } else {
                let iso = isolation.as_deref();
                let ok = |n: &Node| {
                    n.fits(&req)
                        && match (iso, tenant) {
                            (Some(is), Some(t)) => is.allows(t, n.id),
                            _ => true,
                        }
                };
                match locality {
                    None => nodes
                        .iter()
                        .filter(|n| ok(n))
                        .min_by_key(|n| n.free().cpu_m)
                        .map(|n| n.id),
                    Some(h) => {
                        let payload = &pods.payload[i];
                        nodes
                            .iter()
                            .filter(|n| ok(n))
                            .min_by_key(|n| {
                                (std::cmp::Reverse(h.cached_input_bytes(payload, n)), n.free().cpu_m)
                            })
                            .map(|n| n.id)
                    }
                }
            };
            match fit {
                Some(nid) => {
                    nodes[nid.0].alloc(req);
                    pods.phase[i] = PodPhase::Starting;
                    pods.node[i] = Some(nid);
                    pods.scheduled_at[i] = Some(now);
                    if let (Some(iso), Some(t)) = (isolation.as_deref_mut(), tenant) {
                        iso.charge(pid, t, req);
                    }
                    // pipeline the binds to model scheduler throughput
                    self.busy_until =
                        self.busy_until.max(now) + SimTime::from_millis(self.cfg.bind_ms);
                    self.binds_total += 1;
                    out.bound.push((pid, nid, self.busy_until));
                }
                None => {
                    let reason = if !admitted {
                        BackoffReason::Quota
                    } else if any_cordoned
                        && nodes
                            .iter()
                            .any(|n| n.cordoned && n.fits_ignoring_cordon(&req))
                    {
                        self.cordoned_misses += 1;
                        BackoffReason::Cordoned
                    } else {
                        BackoffReason::NoFit
                    };
                    let exp = (self.cfg.backoff_initial_ms as f64
                        * self.cfg.backoff_factor.powi(pods.sched_attempts[i] as i32))
                        as u64;
                    let delay = exp.min(self.cfg.backoff_max_ms);
                    pods.sched_attempts[i] += 1;
                    pods.backoff_until[i] = now + SimTime::from_millis(delay);
                    if !self.sleeping[pid.0 as usize] {
                        self.sleeping[pid.0 as usize] = true;
                        self.sleeping_count += 1;
                    }
                    self.backoffs_total += 1;
                    out.backed_off.push((pid, pods.backoff_until[i]));
                    out.backoff_reasons.push(reason);
                }
            }
        }
    }

    /// Remove a pod from all scheduler queues (pod deleted). The active
    /// deque entry is left in place and skipped lazily on pop.
    pub fn forget(&mut self, pod: PodId) {
        self.ensure(pod);
        let i = pod.0 as usize;
        if self.sleeping[i] {
            self.sleeping[i] = false;
            self.sleeping_count -= 1;
        }
        if self.in_active[i] {
            self.in_active[i] = false;
            self.active_count -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::k8s::node::paper_cluster;
    use crate::k8s::pod::{Payload, Pod};
    use crate::k8s::resources::Resources;
    use crate::workflow::task::TaskId;

    fn mkpod(id: u64, cpu: u64) -> Pod {
        Pod::new(
            PodId(id),
            Payload::JobBatch { tasks: vec![TaskId(0)] },
            Resources::new(cpu, 512),
            SimTime::ZERO,
        )
    }

    /// Decompose row-built pods into the SoA table the scheduler scans.
    fn table(rows: Vec<Pod>) -> PodTable {
        let mut t = PodTable::new();
        for p in rows {
            t.push(p);
        }
        t
    }

    fn run_pass(
        sched: &mut Scheduler,
        now: SimTime,
        pods: &mut PodTable,
        nodes: &mut Vec<Node>,
    ) -> SchedulePass {
        sched.pass(now, pods, nodes)
    }

    #[test]
    fn binds_until_cluster_full_then_backs_off() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(1); // 4000m
        let mut pods = table((0..6).map(|i| mkpod(i, 1000)).collect());
        for i in 0..6 {
            sched.enqueue(PodId(i));
        }
        let pass = run_pass(&mut sched, SimTime::ZERO, &mut pods, &mut nodes);
        assert_eq!(pass.bound.len(), 4);
        assert_eq!(pass.backed_off.len(), 2);
        assert_eq!(pass.backoff_reasons, vec![BackoffReason::NoFit; 2]);
        assert_eq!(sched.sleeping_len(), 2);
        // first back-off is the initial delay
        assert_eq!(pass.backed_off[0].1, SimTime(1_000));
    }

    #[test]
    fn backoff_grows_exponentially_and_caps() {
        let mut sched = Scheduler::new(SchedulerConfig {
            backoff_initial_ms: 1_000,
            backoff_max_ms: 8_000,
            ..Default::default()
        });
        let mut nodes = vec![Node::new(NodeId(0), Resources::new(100, 100))];
        let mut pods = table(vec![mkpod(0, 1000)]); // never fits
        let mut now = SimTime::ZERO;
        let mut delays = Vec::new();
        for _ in 0..6 {
            sched.enqueue(PodId(0));
            let pass = run_pass(&mut sched, now, &mut pods, &mut nodes);
            let until = pass.backed_off[0].1;
            delays.push((until - now).as_millis());
            now = until;
        }
        assert_eq!(delays, vec![1_000, 2_000, 4_000, 8_000, 8_000, 8_000]);
    }

    #[test]
    fn bind_pipeline_spaces_completions() {
        let mut sched = Scheduler::new(SchedulerConfig {
            bind_ms: 10,
            ..Default::default()
        });
        let mut nodes = paper_cluster(2);
        let mut pods = table((0..3).map(|i| mkpod(i, 1000)).collect());
        for i in 0..3 {
            sched.enqueue(PodId(i));
        }
        let pass = run_pass(&mut sched, SimTime(100), &mut pods, &mut nodes);
        let times: Vec<u64> = pass.bound.iter().map(|b| b.2.as_millis()).collect();
        assert_eq!(times, vec![110, 120, 130]);
    }

    #[test]
    fn best_fit_packs_tight_node_first() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(2);
        nodes[0].alloc(Resources::new(3000, 1024)); // node 0 has 1000m free
        let mut pods = table(vec![mkpod(0, 1000)]);
        sched.enqueue(PodId(0));
        let pass = run_pass(&mut sched, SimTime::ZERO, &mut pods, &mut nodes);
        assert_eq!(pass.bound[0].1, NodeId(0)); // tighter fit preferred
    }

    #[test]
    fn deleted_pod_skipped() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(1);
        let mut pods = table(vec![mkpod(0, 1000)]);
        pods.phase[0] = PodPhase::Deleted;
        sched.enqueue(PodId(0));
        let pass = run_pass(&mut sched, SimTime::ZERO, &mut pods, &mut nodes);
        assert!(pass.bound.is_empty());
        assert!(pass.backed_off.is_empty());
    }

    #[test]
    fn forget_removes_everywhere() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        sched.enqueue(PodId(0));
        sched.enqueue(PodId(1));
        sched.forget(PodId(0));
        assert_eq!(sched.queue_len(), 1);
    }

    #[test]
    fn enqueue_dedups() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        sched.enqueue(PodId(0));
        sched.enqueue(PodId(0));
        assert_eq!(sched.queue_len(), 1);
    }

    #[test]
    fn pass_into_reuses_buffer() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(1);
        let mut pods = table((0..2).map(|i| mkpod(i, 1000)).collect());
        sched.enqueue(PodId(0));
        let mut out = SchedulePass::default();
        sched.pass_into(SimTime::ZERO, &mut pods, &mut nodes, &mut out, None, None);
        assert_eq!(out.bound.len(), 1);
        // second pass through the same buffer: stale results are cleared
        sched.enqueue(PodId(1));
        sched.pass_into(SimTime(50), &mut pods, &mut nodes, &mut out, None, None);
        assert_eq!(out.bound.len(), 1);
        assert_eq!(out.bound[0].0, PodId(1));
        assert!(out.backed_off.is_empty());
    }

    // -- back-off bookkeeping at the dense-vector boundary ----------------

    #[test]
    fn out_of_order_pod_ids_grow_the_tables() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(2);
        // ids arrive out of order and far apart: `ensure` must grow the
        // dense flag vectors without disturbing earlier entries
        let n = 70;
        let mut pods = table((0..n).map(|i| mkpod(i, 500)).collect());
        sched.enqueue(PodId(65)); // crosses the first 64-slot growth
        sched.enqueue(PodId(3));
        sched.enqueue(PodId(64));
        assert_eq!(sched.queue_len(), 3);
        let pass = run_pass(&mut sched, SimTime::ZERO, &mut pods, &mut nodes);
        // FIFO order of the active queue is enqueue order, not id order
        let bound: Vec<PodId> = pass.bound.iter().map(|b| b.0).collect();
        assert_eq!(bound, vec![PodId(65), PodId(3), PodId(64)]);
        assert_eq!(sched.queue_len(), 0);
    }

    #[test]
    fn reenqueue_after_backoff_expire_clears_sleeping() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(1); // 4000m
        let mut pods = table((0..5).map(|i| mkpod(i, 1000)).collect());
        for i in 0..5 {
            sched.enqueue(PodId(i));
        }
        let pass = run_pass(&mut sched, SimTime::ZERO, &mut pods, &mut nodes);
        assert_eq!(pass.backed_off.len(), 1);
        let (pid, until) = pass.backed_off[0];
        assert!(sched.is_sleeping(pid));
        assert_eq!(sched.sleeping_len(), 1);
        // free a slot, then deliver the BackoffExpire: re-enqueue must move
        // the pod from sleeping back to active exactly once
        pods.phase[0] = PodPhase::Deleted;
        nodes[0].release(pods.requests[0]);
        sched.forget(PodId(0));
        sched.enqueue(pid);
        assert!(!sched.is_sleeping(pid));
        assert_eq!(sched.sleeping_len(), 0);
        assert_eq!(sched.queue_len(), 1);
        let pass2 = run_pass(&mut sched, until, &mut pods, &mut nodes);
        assert_eq!(pass2.bound.len(), 1);
        assert_eq!(pass2.bound[0].0, pid);
    }

    #[test]
    fn repeated_backoff_keeps_single_sleeping_entry() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = vec![Node::new(NodeId(0), Resources::new(100, 100))];
        let mut pods = table(vec![mkpod(0, 1000)]); // never fits
        let mut now = SimTime::ZERO;
        for _ in 0..4 {
            sched.enqueue(PodId(0));
            let pass = run_pass(&mut sched, now, &mut pods, &mut nodes);
            now = pass.backed_off[0].1;
            assert_eq!(sched.sleeping_len(), 1, "sleeping count must not drift");
            assert_eq!(sched.queue_len(), 0);
        }
        assert_eq!(pods.sched_attempts[0], 4);
    }

    #[test]
    fn forget_unknown_and_sleeping_pods() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        // forgetting a pod the scheduler has never seen grows the tables
        // and is a no-op on the counters
        sched.forget(PodId(129));
        assert_eq!(sched.queue_len(), 0);
        assert_eq!(sched.sleeping_len(), 0);
        // a sleeping pod that gets deleted is fully forgotten
        let mut nodes = vec![Node::new(NodeId(0), Resources::new(100, 100))];
        let mut pods = table(vec![mkpod(0, 1000)]);
        sched.enqueue(PodId(0));
        run_pass(&mut sched, SimTime::ZERO, &mut pods, &mut nodes);
        assert!(sched.is_sleeping(PodId(0)));
        sched.forget(PodId(0));
        assert!(!sched.is_sleeping(PodId(0)));
        assert_eq!(sched.sleeping_len(), 0);
        // a later (stale) wake enqueue re-adds it to active — the driver
        // guards this with `is_sleeping` before enqueueing
        assert!(!sched.is_sleeping(PodId(0)));
    }

    #[test]
    fn cordoned_node_is_skipped_and_counted() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(2);
        nodes[0].cordoned = true;
        // one free slot worth of work on each node; node 0 is draining
        let mut pods = table((0..2).map(|i| mkpod(i, 4000)).collect());
        sched.enqueue(PodId(0));
        sched.enqueue(PodId(1));
        let pass = run_pass(&mut sched, SimTime::ZERO, &mut pods, &mut nodes);
        // only node 1 is placeable; the second pod's miss is attributable
        // to the cordon, not to a full cluster
        assert_eq!(pass.bound.len(), 1);
        assert_eq!(pass.bound[0].1, NodeId(1));
        assert_eq!(pass.backed_off.len(), 1);
        assert_eq!(sched.cordoned_misses, 1);
        // uncordon: the pod now binds to node 0 and no new miss is counted
        nodes[0].cordoned = false;
        sched.enqueue(pass.backed_off[0].0);
        let pass2 = run_pass(&mut sched, SimTime(1_000), &mut pods, &mut nodes);
        assert_eq!(pass2.bound.len(), 1);
        assert_eq!(pass2.bound[0].1, NodeId(0));
        assert_eq!(sched.cordoned_misses, 1);
    }

    #[test]
    fn genuinely_full_cluster_counts_no_cordon_miss() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(1);
        nodes[0].cordoned = true;
        let mut pods = table(vec![mkpod(0, 8000)]); // would not fit even uncordoned
        sched.enqueue(PodId(0));
        let pass = run_pass(&mut sched, SimTime::ZERO, &mut pods, &mut nodes);
        assert_eq!(pass.backed_off.len(), 1);
        assert_eq!(sched.cordoned_misses, 0);
    }

    /// Fixed per-node score table standing in for the data plane.
    struct FakeLocality {
        bytes: Vec<u64>,
    }

    impl DataLocality for FakeLocality {
        fn cached_input_bytes(&self, _payload: &Payload, node: &Node) -> u64 {
            self.bytes[node.id.0]
        }
    }

    #[test]
    fn locality_score_beats_best_fit_but_zero_score_degenerates_to_it() {
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(3);
        // node 0 is the best-fit choice (tightest), node 2 caches the data
        nodes[0].alloc(Resources::new(3000, 1024));
        let mut pods = table(vec![mkpod(0, 1000), mkpod(1, 1000)]);
        let hint = FakeLocality {
            bytes: vec![0, 0, 4096],
        };
        sched.enqueue(PodId(0));
        let mut out = SchedulePass::default();
        sched.pass_into(SimTime::ZERO, &mut pods, &mut nodes, &mut out, Some(&hint), None);
        assert_eq!(out.bound[0].1, NodeId(2), "cached bytes win placement");
        // an all-zero score must reproduce the best-fit pick exactly
        let cold = FakeLocality {
            bytes: vec![0, 0, 0],
        };
        sched.enqueue(PodId(1));
        sched.pass_into(SimTime(10), &mut pods, &mut nodes, &mut out, Some(&cold), None);
        assert_eq!(out.bound[0].1, NodeId(0), "zero score falls back to best-fit");
    }

    #[test]
    fn never_overallocates_nodes() {
        // property-style: random pods, after every pass allocation <= capacity
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        for _ in 0..20 {
            let mut sched = Scheduler::new(SchedulerConfig::default());
            let mut nodes = paper_cluster(3);
            let n = 30 + rng.below(40);
            let mut pods = table(
                (0..n).map(|i| mkpod(i, 250 + rng.below(16) * 250)).collect(),
            );
            for i in 0..n {
                sched.enqueue(PodId(i));
            }
            run_pass(&mut sched, SimTime::ZERO, &mut pods, &mut nodes);
            for node in &nodes {
                assert!(node.allocated.cpu_m <= node.capacity.cpu_m);
                assert!(node.allocated.mem_mb <= node.capacity.mem_mb);
            }
        }
    }

    // -- isolation oracle: quota admission + placement constraints --------

    use crate::k8s::isolation::{
        IsolationConfig, IsolationPolicy, IsolationState, SHARED_TENANT,
    };

    #[test]
    fn quota_throttles_then_admits_after_release() {
        let cfg = IsolationConfig::parse_spec("shared,quota:1000x4096").unwrap();
        let mut iso = IsolationState::new(cfg, 1);
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(1); // 4000m — plenty; only quota binds
        let mut pods = table((0..2).map(|i| mkpod(i, 1000)).collect());
        for i in 0..2 {
            iso.on_pod_created(PodId(i), 0, pods.requests[i as usize]);
            sched.enqueue(PodId(i));
        }
        let mut out = SchedulePass::default();
        sched.pass_into(SimTime::ZERO, &mut pods, &mut nodes, &mut out, None, Some(&mut iso));
        // first pod fills the 1000m namespace quota; second is throttled
        // into back-off, not bound
        assert_eq!(out.bound.len(), 1);
        assert_eq!(out.bound[0].0, PodId(0));
        assert_eq!(out.backed_off.len(), 1);
        assert_eq!(iso.stats.quota_throttles_by_tenant, vec![1]);
        // quota frees with the pod: the throttled pod then binds
        iso.release(PodId(0));
        sched.enqueue(PodId(1));
        sched.pass_into(SimTime(2_000), &mut pods, &mut nodes, &mut out, None, Some(&mut iso));
        assert_eq!(out.bound.len(), 1);
        assert_eq!(out.bound[0].0, PodId(1));
    }

    #[test]
    fn dedicated_policy_constrains_placement_to_owned_nodes() {
        let mut iso = IsolationState::new(
            IsolationConfig::new(IsolationPolicy::Dedicated),
            2,
        );
        iso.set_tenants(&[1, 1]); // node 0 -> tenant 0, node 1 -> tenant 1
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(2);
        // make the foreign node 0 the best-fit winner: only the pool
        // constraint can steer the pod to node 1
        nodes[0].alloc(Resources::new(3000, 1024));
        let mut pods = table(vec![mkpod(0, 1000)]);
        iso.on_pod_created(PodId(0), 1, pods.requests[0]);
        sched.enqueue(PodId(0));
        let mut out = SchedulePass::default();
        sched.pass_into(SimTime::ZERO, &mut pods, &mut nodes, &mut out, None, Some(&mut iso));
        assert_eq!(out.bound.len(), 1);
        assert_eq!(out.bound[0].1, NodeId(1), "tenant 1 must land in its own pool");
        assert_eq!(iso.stats.quota_throttles_by_tenant.iter().sum::<u64>(), 0);
    }

    #[test]
    fn shared_tenant_pods_bind_anywhere_under_dedicated_policy() {
        let mut iso = IsolationState::new(
            IsolationConfig::new(IsolationPolicy::Dedicated),
            2,
        );
        iso.set_tenants(&[1, 1]);
        let mut sched = Scheduler::new(SchedulerConfig::default());
        let mut nodes = paper_cluster(2);
        nodes[0].alloc(Resources::new(3000, 1024)); // node 0 is best-fit
        let mut pods = table(vec![mkpod(0, 1000)]);
        // infra/worker pods carry the shared sentinel and ignore pools
        iso.on_pod_created(PodId(0), SHARED_TENANT, pods.requests[0]);
        sched.enqueue(PodId(0));
        let mut out = SchedulePass::default();
        sched.pass_into(SimTime::ZERO, &mut pods, &mut nodes, &mut out, None, Some(&mut iso));
        assert_eq!(out.bound.len(), 1);
        assert_eq!(out.bound[0].1, NodeId(0), "shared pods keep plain best-fit");
    }
}
