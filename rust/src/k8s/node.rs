//! Cluster nodes: capacity accounting for the scheduler.

use super::resources::Resources;

/// Index of a node in the cluster's node list.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

/// A worker node. `allocated` tracks the sum of requests of pods bound to
/// this node; Kubernetes' max-pods-per-node (default 110) is enforced too.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub capacity: Resources,
    pub allocated: Resources,
    pub pods: usize,
    pub max_pods: usize,
    /// Failure injection: a failed node schedules nothing until recovery.
    pub failed: bool,
    /// Cordoned: running pods continue, but the scheduler places nothing
    /// new here (chaos: spot-reclaim drain warnings, blacklisted nodes).
    pub cordoned: bool,
}

impl Node {
    pub fn new(id: NodeId, capacity: Resources) -> Self {
        Node {
            id,
            capacity,
            allocated: Resources::ZERO,
            pods: 0,
            max_pods: 110,
            failed: false,
            cordoned: false,
        }
    }

    pub fn free(&self) -> Resources {
        self.capacity.saturating_sub(self.allocated)
    }

    pub fn fits(&self, req: &Resources) -> bool {
        !self.cordoned && self.fits_ignoring_cordon(req)
    }

    /// Capacity check without the cordon taint — used by the scheduler to
    /// tell "cluster full" apart from "capacity exists but is cordoned".
    pub fn fits_ignoring_cordon(&self, req: &Resources) -> bool {
        !self.failed && self.pods < self.max_pods && self.free().covers(req)
    }

    /// Bind a pod's requests to this node. Panics in debug builds if the
    /// pod does not fit — the scheduler must check `fits` first.
    pub fn alloc(&mut self, req: Resources) {
        debug_assert!(self.fits(&req), "alloc without fits check");
        self.allocated += req;
        self.pods += 1;
    }

    pub fn release(&mut self, req: Resources) {
        debug_assert!(self.pods > 0);
        self.allocated = self.allocated.saturating_sub(req);
        self.pods -= 1;
    }

    /// Fraction of CPU capacity currently allocated (for utilization plots).
    pub fn cpu_utilization(&self) -> f64 {
        if self.capacity.cpu_m == 0 {
            return 0.0;
        }
        self.allocated.cpu_m as f64 / self.capacity.cpu_m as f64
    }
}

/// Build the paper's cluster: `n` worker nodes of 4 vCPU / 16 GiB (§4.1).
pub fn paper_cluster(n: usize) -> Vec<Node> {
    (0..n)
        .map(|i| Node::new(NodeId(i), Resources::paper_node()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_cycle() {
        let mut n = Node::new(NodeId(0), Resources::new(4000, 16384));
        let req = Resources::new(1000, 2048);
        assert!(n.fits(&req));
        n.alloc(req);
        assert_eq!(n.free(), Resources::new(3000, 14336));
        assert_eq!(n.pods, 1);
        n.release(req);
        assert_eq!(n.free(), Resources::new(4000, 16384));
        assert_eq!(n.pods, 0);
    }

    #[test]
    fn fits_respects_cpu_exhaustion() {
        let mut n = Node::new(NodeId(0), Resources::new(4000, 16384));
        for _ in 0..4 {
            n.alloc(Resources::new(1000, 1024));
        }
        assert!(!n.fits(&Resources::new(1000, 1024)));
        assert!(n.fits(&Resources::new(0, 1024)) == false || n.free().cpu_m == 0);
    }

    #[test]
    fn fits_respects_max_pods() {
        let mut n = Node::new(NodeId(0), Resources::new(400_000, 400_000));
        n.max_pods = 3;
        for _ in 0..3 {
            n.alloc(Resources::new(1, 1));
        }
        assert!(!n.fits(&Resources::new(1, 1)));
    }

    #[test]
    fn cpu_utilization_fraction() {
        let mut n = Node::new(NodeId(0), Resources::new(4000, 16384));
        n.alloc(Resources::new(1000, 1024));
        assert!((n.cpu_utilization() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn cordon_blocks_placement_but_not_capacity() {
        let mut n = Node::new(NodeId(0), Resources::new(4000, 16384));
        let req = Resources::new(1000, 1024);
        n.cordoned = true;
        assert!(!n.fits(&req), "cordoned node must reject placements");
        assert!(
            n.fits_ignoring_cordon(&req),
            "capacity itself is still there"
        );
        n.failed = true;
        assert!(!n.fits_ignoring_cordon(&req), "failed trumps everything");
    }

    #[test]
    fn paper_cluster_shape() {
        let c = paper_cluster(17);
        assert_eq!(c.len(), 17);
        let total_cores: u64 = c.iter().map(|n| n.capacity.cpu_m).sum::<u64>() / 1000;
        assert_eq!(total_cores, 68); // "up to 68 cores" (§4.1)
    }
}
