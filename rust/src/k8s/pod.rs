//! Pods: the unit of scheduling. A pod either runs a (possibly clustered)
//! batch of workflow tasks to completion (job-based models) or is a
//! long-lived worker in a pool (worker-pools model).

use super::node::NodeId;
use super::resources::Resources;
use crate::broker::PoolId;
use crate::sim::SimTime;
use crate::workflow::task::TaskId;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PodId(pub u64);

/// What runs inside the pod.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Payload {
    /// Job-based execution: run these workflow tasks sequentially, then the
    /// pod terminates (task clustering = len > 1; plain job model = len 1).
    JobBatch { tasks: Vec<TaskId> },
    /// Worker-pools execution: long-running worker consuming from the
    /// pool's queue. The pool is an interned [`PoolId`] so routing a pod
    /// event never touches (or clones) a string (EXPERIMENTS.md §Perf).
    Worker { pool: PoolId },
}

/// Pod lifecycle. The paper's job-model pathologies live in
/// Pending (scheduler back-off) and Starting (the ~2 s creation overhead).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PodPhase {
    /// Created, waiting for the scheduler.
    Pending,
    /// Bound to a node; container starting (image pull/sandbox ≈ 2 s).
    Starting,
    /// Executing payload.
    Running,
    /// Worker asked to terminate after current task (scale-down).
    Draining,
    /// Batch finished / worker terminated. Resources released.
    Succeeded,
    /// Deleted by the deployment controller (scale-down of an idle worker).
    Deleted,
}

#[derive(Debug, Clone)]
pub struct Pod {
    pub id: PodId,
    pub payload: Payload,
    pub requests: Resources,
    pub phase: PodPhase,
    pub node: Option<NodeId>,
    /// Scheduling back-off bookkeeping (attempt count).
    pub sched_attempts: u32,
    /// When the pod may next be retried by the scheduler.
    pub backoff_until: SimTime,
    // -- trace timestamps ------------------------------------------------
    pub created_at: SimTime,
    pub scheduled_at: Option<SimTime>,
    pub running_at: Option<SimTime>,
    pub finished_at: Option<SimTime>,
    /// Tasks executed in this pod (for trace/pod-churn accounting).
    pub executed: u32,
}

impl Pod {
    pub fn new(id: PodId, payload: Payload, requests: Resources, now: SimTime) -> Self {
        Pod {
            id,
            payload,
            requests,
            phase: PodPhase::Pending,
            node: None,
            sched_attempts: 0,
            backoff_until: SimTime::ZERO,
            created_at: now,
            scheduled_at: None,
            running_at: None,
            finished_at: None,
            executed: 0,
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self.phase, PodPhase::Succeeded | PodPhase::Deleted)
    }

    /// The pool a worker pod belongs to (`None` for job pods).
    pub fn pool_id(&self) -> Option<PoolId> {
        match &self.payload {
            Payload::Worker { pool } => Some(*pool),
            Payload::JobBatch { .. } => None,
        }
    }
}

/// SoA pod lifecycle table: one parallel `Vec` per [`Pod`] field, keyed
/// by the dense `PodId` index (matching the `PoolId(u16)` interning
/// pattern — EXPERIMENTS.md §Perf). The exec kernel's hot paths touch
/// one or two fields of many pods per event (phase checks in the
/// scheduler pass, node lookups on task start), so splitting the struct
/// keeps those scans on dense homogeneous arrays instead of striding
/// over whole `Pod` rows.
///
/// [`Pod`] itself survives as the *row/builder* value type: callers and
/// tests still construct a `Pod` and [`PodTable::push`] decomposes it.
/// `PodId` is implicit — row `i` is pod `i`.
#[derive(Debug, Default)]
pub struct PodTable {
    pub payload: Vec<Payload>,
    pub requests: Vec<Resources>,
    pub phase: Vec<PodPhase>,
    pub node: Vec<Option<NodeId>>,
    /// Scheduling back-off bookkeeping (attempt count).
    pub sched_attempts: Vec<u32>,
    /// When each pod may next be retried by the scheduler.
    pub backoff_until: Vec<SimTime>,
    // -- trace timestamps ------------------------------------------------
    pub created_at: Vec<SimTime>,
    pub scheduled_at: Vec<Option<SimTime>>,
    pub running_at: Vec<Option<SimTime>>,
    pub finished_at: Vec<Option<SimTime>>,
    /// Tasks executed in each pod (for trace/pod-churn accounting).
    pub executed: Vec<u32>,
}

impl PodTable {
    pub fn new() -> Self {
        PodTable::default()
    }

    pub fn len(&self) -> usize {
        self.phase.len()
    }

    pub fn is_empty(&self) -> bool {
        self.phase.is_empty()
    }

    /// Append a pod row. The row's `id` must equal the next index — pods
    /// are append-only and dense, exactly like the old `Vec<Pod>`.
    pub fn push(&mut self, pod: Pod) {
        debug_assert_eq!(pod.id.0 as usize, self.len(), "pod ids must be dense");
        self.payload.push(pod.payload);
        self.requests.push(pod.requests);
        self.phase.push(pod.phase);
        self.node.push(pod.node);
        self.sched_attempts.push(pod.sched_attempts);
        self.backoff_until.push(pod.backoff_until);
        self.created_at.push(pod.created_at);
        self.scheduled_at.push(pod.scheduled_at);
        self.running_at.push(pod.running_at);
        self.finished_at.push(pod.finished_at);
        self.executed.push(pod.executed);
    }

    pub fn is_terminal(&self, i: usize) -> bool {
        matches!(self.phase[i], PodPhase::Succeeded | PodPhase::Deleted)
    }

    /// The pool a worker pod belongs to (`None` for job pods).
    pub fn pool_id(&self, i: usize) -> Option<PoolId> {
        match &self.payload[i] {
            Payload::Worker { pool } => Some(*pool),
            Payload::JobBatch { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_pod_is_pending() {
        let p = Pod::new(
            PodId(1),
            Payload::JobBatch { tasks: vec![TaskId(0)] },
            Resources::new(500, 512),
            SimTime(10),
        );
        assert_eq!(p.phase, PodPhase::Pending);
        assert_eq!(p.created_at, SimTime(10));
        assert!(!p.is_terminal());
        assert_eq!(p.pool_id(), None);
    }

    #[test]
    fn worker_pool_id() {
        let p = Pod::new(
            PodId(2),
            Payload::Worker { pool: PoolId(3) },
            Resources::new(1000, 1024),
            SimTime::ZERO,
        );
        assert_eq!(p.pool_id(), Some(PoolId(3)));
    }

    #[test]
    fn terminal_phases() {
        let mut p = Pod::new(
            PodId(3),
            Payload::JobBatch { tasks: vec![] },
            Resources::ZERO,
            SimTime::ZERO,
        );
        p.phase = PodPhase::Succeeded;
        assert!(p.is_terminal());
        p.phase = PodPhase::Deleted;
        assert!(p.is_terminal());
        p.phase = PodPhase::Draining;
        assert!(!p.is_terminal());
    }

    #[test]
    fn table_push_decomposes_rows_and_mirrors_row_queries() {
        let mut t = PodTable::new();
        assert!(t.is_empty());
        t.push(Pod::new(
            PodId(0),
            Payload::JobBatch { tasks: vec![TaskId(4)] },
            Resources::new(500, 512),
            SimTime(10),
        ));
        t.push(Pod::new(
            PodId(1),
            Payload::Worker { pool: PoolId(3) },
            Resources::new(1000, 1024),
            SimTime(20),
        ));
        assert_eq!(t.len(), 2);
        assert_eq!(t.phase[0], PodPhase::Pending);
        assert_eq!(t.created_at[1], SimTime(20));
        assert_eq!(t.pool_id(0), None);
        assert_eq!(t.pool_id(1), Some(PoolId(3)));
        assert!(!t.is_terminal(0));
        t.phase[0] = PodPhase::Succeeded;
        assert!(t.is_terminal(0));
        t.phase[1] = PodPhase::Draining;
        assert!(!t.is_terminal(1));
    }

    #[test]
    #[should_panic(expected = "pod ids must be dense")]
    #[cfg(debug_assertions)]
    fn table_rejects_sparse_ids() {
        let mut t = PodTable::new();
        t.push(Pod::new(
            PodId(7),
            Payload::JobBatch { tasks: vec![] },
            Resources::ZERO,
            SimTime::ZERO,
        ));
    }
}
