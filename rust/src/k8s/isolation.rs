//! Tenant isolation: namespaces, ResourceQuotas, LimitRanges and
//! node-pool isolation policies.
//!
//! The paper's worker-pools model wins on utilization precisely because
//! tenants share one elastic cluster (§3.4) — but sharing without
//! boundaries means one misbehaving (or compromised) tenant can starve
//! or crash everyone. Real cloud-native platforms bound that risk with
//! Kubernetes namespaces, ResourceQuota/LimitRange admission control and
//! node-pool isolation (cf. "Resource Management Schemes for Cloud-Native
//! Platforms with Computing Containers of Docker and Kubernetes",
//! PAPERS.md). This module makes those boundaries first-class and
//! *measurable*:
//!
//! * [`IsolationPolicy`] — `shared` (one node pool, quota-only
//!   boundaries), `dedicated` (nodes partitioned into per-tenant pools,
//!   enforced as taint/toleration-style placement constraints in the
//!   scheduler), `sandboxed` (dedicated pools **plus** a hardened
//!   runtime: no container-to-node escape, at the price of a fixed pod
//!   sandbox-start overhead);
//! * [`ResourceQuota`] — per-tenant namespace quota (cpu/mem/pod-count)
//!   enforced when a pod is admitted to a node. Kubernetes rejects the
//!   pod at the apiserver and the owning controller retries; the
//!   simulator folds that reject-and-retry loop into the scheduler's
//!   existing exponential back-off, counting each deferral as a
//!   *quota throttle*;
//! * [`crate::k8s::resources::LimitRange`] — namespace request
//!   defaulting/floor applied at pod creation;
//! * [`IsolationState`] — the runtime ledger: node ownership, per-pod
//!   namespace stamps, quota usage, and the violation/throttle counters
//!   surfaced in [`IsolationReport`] and the fleet SLO table.
//!
//! The blast-radius/privilege side of isolation (what a *compromised*
//! tenant can reach) lives in [`crate::chaos::takeover`]; this module
//! supplies it the ownership facts it needs.
//!
//! With `SimConfig.isolation == None` nothing here is constructed and
//! every run is bit-identical to an isolation-unaware build.

use super::node::NodeId;
use super::pod::{Payload, PodId};
use super::resources::{LimitRange, Resources};
use crate::util::json::Json;

/// Namespace stamp for infrastructure pods (worker pools serve every
/// tenant through broker lanes, so the pod itself belongs to no tenant;
/// the task it currently executes does).
pub const SHARED_TENANT: u16 = u16::MAX;

/// How tenant workloads map onto node pools.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IsolationPolicy {
    /// One shared node pool: quotas bound *how much* a tenant uses, not
    /// *where* it runs.
    Shared,
    /// Nodes are partitioned into per-tenant pools (fair-share-weighted
    /// largest-remainder split); a tenant's pods only bind to its own
    /// pool, and pool workers only serve the tenant owning their node.
    Dedicated,
    /// Dedicated pools plus a hardened pod runtime (gVisor/Kata-style):
    /// container-to-node escape is denied, so a takeover's blast radius
    /// is the victim's own pods — at the price of
    /// [`SANDBOX_START_OVERHEAD_MS`] extra pod start latency.
    Sandboxed,
}

/// Extra pod start latency under [`IsolationPolicy::Sandboxed`]: a
/// hardened runtime boots a guest kernel / userspace proxy per pod.
pub const SANDBOX_START_OVERHEAD_MS: u64 = 1_500;

impl IsolationPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            IsolationPolicy::Shared => "shared",
            IsolationPolicy::Dedicated => "dedicated",
            IsolationPolicy::Sandboxed => "sandboxed",
        }
    }

    /// Do per-tenant node pools exist (placement + fetch constraints)?
    pub fn partitions_nodes(&self) -> bool {
        !matches!(self, IsolationPolicy::Shared)
    }

    /// Can a compromised container escape onto its node (and from there
    /// reach co-resident pods and node-local caches)?
    pub fn can_reach_node(&self) -> bool {
        !matches!(self, IsolationPolicy::Sandboxed)
    }

    /// Pod start overhead the runtime class adds.
    pub fn start_overhead_ms(&self) -> u64 {
        match self {
            IsolationPolicy::Sandboxed => SANDBOX_START_OVERHEAD_MS,
            _ => 0,
        }
    }
}

/// Per-tenant namespace quota (the Kubernetes `ResourceQuota` object):
/// aggregate requests and pod count a namespace may hold at once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResourceQuota {
    pub cpu_m: u64,
    pub mem_mb: u64,
    /// Max concurrently admitted pods (`None` = unbounded).
    pub pods: Option<u64>,
}

/// Parsed `--isolation` spec.
///
/// Grammar: `shared|dedicated|sandboxed[,quota:<cpu_m>x<mem_mb>]`
/// `[,pods:<n>][,limit:<cpu_m>x<mem_mb>]`
/// — e.g. `dedicated,quota:8000x32768,pods:50,limit:250x512`.
#[derive(Debug, Clone, PartialEq)]
pub struct IsolationConfig {
    pub policy: IsolationPolicy,
    /// Per-tenant namespace quota (admission-time enforcement).
    pub quota: Option<ResourceQuota>,
    /// Namespace LimitRange: request defaulting/floor at pod creation.
    pub limit: Option<LimitRange>,
}

impl IsolationConfig {
    pub fn new(policy: IsolationPolicy) -> Self {
        IsolationConfig {
            policy,
            quota: None,
            limit: None,
        }
    }

    /// Parse the CLI/bench spec. Errors are sentences naming the bad
    /// entry (the CLI prefixes them with `--isolation:`).
    pub fn parse_spec(spec: &str) -> Result<IsolationConfig, String> {
        let mut parts = spec.split(',').map(str::trim).filter(|p| !p.is_empty());
        let policy = match parts.next() {
            Some("shared") => IsolationPolicy::Shared,
            Some("dedicated") => IsolationPolicy::Dedicated,
            Some("sandboxed") => IsolationPolicy::Sandboxed,
            Some(other) => {
                return Err(format!(
                    "isolation policy '{other}' is not one of shared, dedicated, sandboxed"
                ))
            }
            None => return Err("empty isolation spec (expected a policy)".into()),
        };
        let mut cfg = IsolationConfig::new(policy);
        for entry in parts {
            let (kind, value) = entry
                .split_once(':')
                .ok_or_else(|| format!("isolation entry '{entry}' is not kind:value"))?;
            match kind.trim() {
                "quota" => {
                    let (cpu_m, mem_mb) = parse_pair(entry, value)?;
                    let pods = cfg.quota.and_then(|q| q.pods);
                    cfg.quota = Some(ResourceQuota { cpu_m, mem_mb, pods });
                }
                "pods" => {
                    let n: u64 = value.trim().parse().map_err(|_| {
                        format!("isolation entry '{entry}': '{value}' is not a pod count")
                    })?;
                    let mut q = cfg.quota.unwrap_or(ResourceQuota {
                        cpu_m: u64::MAX,
                        mem_mb: u64::MAX,
                        pods: None,
                    });
                    q.pods = Some(n);
                    cfg.quota = Some(q);
                }
                "limit" => {
                    let (cpu_m, mem_mb) = parse_pair(entry, value)?;
                    cfg.limit = Some(LimitRange {
                        default: Resources::new(cpu_m, mem_mb),
                    });
                }
                other => {
                    return Err(format!(
                        "unknown isolation entry '{other}' (expected quota, pods, limit)"
                    ))
                }
            }
        }
        Ok(cfg)
    }

    /// One-line summary for CLI banners.
    pub fn describe(&self) -> String {
        let mut s = String::from(self.policy.name());
        if let Some(q) = &self.quota {
            if q.cpu_m != u64::MAX {
                s.push_str(&format!(" quota={}m x {}Mi", q.cpu_m, q.mem_mb));
            }
            if let Some(p) = q.pods {
                s.push_str(&format!(" pods<={p}"));
            }
        }
        if let Some(l) = &self.limit {
            s.push_str(&format!(
                " limit={}m x {}Mi",
                l.default.cpu_m, l.default.mem_mb
            ));
        }
        s
    }
}

fn parse_pair(entry: &str, value: &str) -> Result<(u64, u64), String> {
    let (cpu, mem) = value.split_once('x').ok_or_else(|| {
        format!("isolation entry '{entry}': '{value}' is not <cpu_m>x<mem_mb>")
    })?;
    let cpu_m: u64 = cpu.trim().parse().map_err(|_| {
        format!("isolation entry '{entry}': '{cpu}' is not a millicore count")
    })?;
    let mem_mb: u64 = mem.trim().parse().map_err(|_| {
        format!("isolation entry '{entry}': '{mem}' is not a MiB count")
    })?;
    Ok((cpu_m, mem_mb))
}

/// Per-run isolation counters (dense per-tenant lanes, fleet-sized by
/// [`IsolationState::set_tenants`]).
#[derive(Debug, Default, Clone)]
pub struct IsolationStats {
    /// Scheduler deferrals because the namespace quota was full.
    pub quota_throttles_by_tenant: Vec<u64>,
    /// Tasks that started on capacity owned by *another* tenant under a
    /// partitioning policy (e.g. a mixed-tenant clustered batch).
    pub violations_by_tenant: Vec<u64>,
    /// Compute-ms innocent tenants had in flight inside takeover blast
    /// radii at takeover time (their exposure to the remediation drain).
    pub takeover_exposed_ms_by_tenant: Vec<u64>,
    pub takeovers: u64,
    pub blast_nodes_total: u64,
    pub blast_pods_total: u64,
    pub blast_innocent_pods_total: u64,
    pub blast_storage_surfaces_total: u64,
}

impl IsolationStats {
    fn lane(v: &mut Vec<u64>, tenant: u16) -> &mut u64 {
        // clamp unknown/oversized tenants to the last lane, matching
        // ChaosStats semantics
        let i = (tenant as usize).min(v.len().saturating_sub(1));
        &mut v[i]
    }

    pub fn add_throttle(&mut self, tenant: u16) {
        *Self::lane(&mut self.quota_throttles_by_tenant, tenant) += 1;
    }

    pub fn add_violation(&mut self, tenant: u16) {
        *Self::lane(&mut self.violations_by_tenant, tenant) += 1;
    }

    pub fn add_exposure(&mut self, tenant: u16, ms: u64) {
        *Self::lane(&mut self.takeover_exposed_ms_by_tenant, tenant) += ms;
    }
}

/// Runtime ledger: who owns which node, which namespace each pod lives
/// in, and how much of each quota is in use.
#[derive(Debug)]
pub struct IsolationState {
    pub cfg: IsolationConfig,
    /// Owning tenant per node (`None` = shared pool). Empty vector under
    /// [`IsolationPolicy::Shared`].
    node_tenant: Vec<Option<u16>>,
    /// Namespace stamp per pod ([`SHARED_TENANT`] for pool workers).
    pod_tenant: Vec<u16>,
    /// Quota charged at bind per pod (released with the pod).
    charged: Vec<Option<Resources>>,
    /// Per-tenant quota usage.
    used: Vec<Resources>,
    used_pods: Vec<u64>,
    n_tenants: usize,
    n_nodes: usize,
    pub stats: IsolationStats,
}

impl IsolationState {
    /// Build for a single-tenant run; [`IsolationState::set_tenants`]
    /// re-partitions when a fleet plan arrives.
    pub fn new(cfg: IsolationConfig, n_nodes: usize) -> Self {
        let mut s = IsolationState {
            cfg,
            node_tenant: Vec::new(),
            pod_tenant: Vec::new(),
            charged: Vec::new(),
            used: Vec::new(),
            used_pods: Vec::new(),
            n_tenants: 0,
            n_nodes,
            stats: IsolationStats::default(),
        };
        s.set_tenants(&[1]);
        s
    }

    /// Size the per-tenant lanes and (re)partition the node pools by
    /// fair-share weight. Called before any event runs, so partitioning
    /// is part of the deterministic initial state.
    pub fn set_tenants(&mut self, weights: &[u64]) {
        let n = weights.len().max(1);
        self.n_tenants = n;
        self.used = vec![Resources::ZERO; n];
        self.used_pods = vec![0; n];
        self.stats.quota_throttles_by_tenant = vec![0; n];
        self.stats.violations_by_tenant = vec![0; n];
        self.stats.takeover_exposed_ms_by_tenant = vec![0; n];
        self.node_tenant = if self.cfg.policy.partitions_nodes() {
            // weighted largest-remainder split of the node count: tenant
            // t owns a contiguous block of `counts[t]` nodes
            let unit = vec![1u64; n];
            let w = if weights.iter().all(|&x| x == 0) { &unit } else { weights };
            let counts = crate::autoscale::split_quota(self.n_nodes as u64, w);
            let mut owners = Vec::with_capacity(self.n_nodes);
            for (t, &c) in counts.iter().enumerate() {
                for _ in 0..c {
                    owners.push(Some(t as u16));
                }
            }
            // remainder nodes (all-zero weights edge) stay shared
            owners.resize(self.n_nodes, None);
            owners
        } else {
            Vec::new()
        };
    }

    pub fn n_tenants(&self) -> usize {
        self.n_tenants
    }

    /// Owning tenant of a node (`None` = shared pool / shared policy).
    pub fn node_owner(&self, node: NodeId) -> Option<u16> {
        self.node_tenant.get(node.0).copied().flatten()
    }

    /// Do worker fetches have to stay within the node owner's lane?
    pub fn constrains_fetch(&self) -> bool {
        self.cfg.policy.partitions_nodes()
    }

    /// Stamp a new pod with its namespace and apply the LimitRange
    /// default/floor to its requests. Returns the effective requests.
    pub fn on_pod_created(&mut self, pod: PodId, tenant: u16, requests: Resources) -> Resources {
        let i = pod.0 as usize;
        if i >= self.pod_tenant.len() {
            self.pod_tenant.resize(i + 1, SHARED_TENANT);
            self.charged.resize(i + 1, None);
        }
        self.pod_tenant[i] = tenant;
        match &self.cfg.limit {
            Some(lr) => lr.apply(requests),
            None => requests,
        }
    }

    /// Namespace of a pod ([`SHARED_TENANT`] when unstamped — pods
    /// created before the state existed never happen, but stay safe).
    pub fn tenant_of_pod(&self, pod: PodId) -> u16 {
        self.pod_tenant
            .get(pod.0 as usize)
            .copied()
            .unwrap_or(SHARED_TENANT)
    }

    /// The tenant whose *work* a pod currently embodies: the namespace
    /// for tenant-owned pods, the running task's tenant for pool
    /// workers, `None` for idle infrastructure. Takes the pod's id and
    /// payload column rather than a whole row so the SoA
    /// [`super::pod::PodTable`] callers avoid materializing a `Pod`.
    pub fn effective_tenant(
        &self,
        pod: PodId,
        payload: &Payload,
        current_task_tenant: Option<u16>,
    ) -> Option<u16> {
        match payload {
            Payload::JobBatch { .. } => Some(self.tenant_of_pod(pod)),
            Payload::Worker { .. } => current_task_tenant,
        }
    }

    /// Placement constraint (the taint/toleration check): may `tenant`'s
    /// pod bind to `node`? Shared-namespace infrastructure pods tolerate
    /// every pool — the node's owner then bounds whose work they serve
    /// (see `Broker::fetch_from`).
    pub fn allows(&self, tenant: u16, node: NodeId) -> bool {
        match self.node_owner(node) {
            None => true,
            Some(owner) => tenant == SHARED_TENANT || tenant == owner,
        }
    }

    /// Admission check against the namespace quota. Infrastructure pods
    /// are not namespaced and always pass.
    pub fn admits(&self, tenant: u16, requests: Resources) -> bool {
        if tenant == SHARED_TENANT {
            return true;
        }
        let Some(q) = &self.cfg.quota else { return true };
        let t = (tenant as usize).min(self.n_tenants - 1);
        if let Some(cap) = q.pods {
            if self.used_pods[t] >= cap {
                return false;
            }
        }
        let u = self.used[t];
        u.cpu_m.saturating_add(requests.cpu_m) <= q.cpu_m
            && u.mem_mb.saturating_add(requests.mem_mb) <= q.mem_mb
    }

    /// Charge the quota for a pod that just bound.
    pub fn charge(&mut self, pod: PodId, tenant: u16, requests: Resources) {
        if tenant == SHARED_TENANT || self.cfg.quota.is_none() {
            return;
        }
        let t = (tenant as usize).min(self.n_tenants - 1);
        self.used[t] = self.used[t] + requests;
        self.used_pods[t] += 1;
        let i = pod.0 as usize;
        if i >= self.charged.len() {
            self.charged.resize(i + 1, None);
            self.pod_tenant.resize(i + 1, SHARED_TENANT);
        }
        self.charged[i] = Some(requests);
    }

    /// Release a pod's quota charge (no-op for pods that never bound).
    pub fn release(&mut self, pod: PodId) {
        let i = pod.0 as usize;
        let Some(req) = self.charged.get_mut(i).and_then(|c| c.take()) else {
            return;
        };
        let tenant = self.pod_tenant[i];
        let t = (tenant as usize).min(self.n_tenants - 1);
        self.used[t] = self.used[t].saturating_sub(req);
        self.used_pods[t] = self.used_pods[t].saturating_sub(1);
    }

    /// Record a task start on `node`; counts an isolation violation when
    /// the task runs on capacity owned by another tenant.
    pub fn note_task_start(&mut self, task_tenant: u16, node: NodeId) {
        if let Some(owner) = self.node_owner(node) {
            if owner != task_tenant {
                self.stats.add_violation(task_tenant);
            }
        }
    }

    /// Quota currently in use by a tenant (test/report hook).
    pub fn used_by(&self, tenant: u16) -> (Resources, u64) {
        let t = (tenant as usize).min(self.n_tenants - 1);
        (self.used[t], self.used_pods[t])
    }

    pub fn report(&self) -> IsolationReport {
        IsolationReport {
            enabled: true,
            policy: self.cfg.policy.name().to_string(),
            quota_throttles_by_tenant: self.stats.quota_throttles_by_tenant.clone(),
            violations_by_tenant: self.stats.violations_by_tenant.clone(),
            takeover_exposed_ms_by_tenant: self.stats.takeover_exposed_ms_by_tenant.clone(),
            takeovers: self.stats.takeovers,
            blast_nodes: self.stats.blast_nodes_total,
            blast_pods: self.stats.blast_pods_total,
            blast_innocent_pods: self.stats.blast_innocent_pods_total,
            blast_storage_surfaces: self.stats.blast_storage_surfaces_total,
        }
    }
}

/// Isolation accounting attached to every [`crate::report::SimResult`]
/// (all-zero, `enabled == false`, when isolation is off).
#[derive(Debug, Default, Clone)]
pub struct IsolationReport {
    pub enabled: bool,
    pub policy: String,
    pub quota_throttles_by_tenant: Vec<u64>,
    pub violations_by_tenant: Vec<u64>,
    pub takeover_exposed_ms_by_tenant: Vec<u64>,
    pub takeovers: u64,
    /// Blast-radius sizes summed over all takeovers this run.
    pub blast_nodes: u64,
    pub blast_pods: u64,
    pub blast_innocent_pods: u64,
    pub blast_storage_surfaces: u64,
}

impl IsolationReport {
    pub fn quota_throttles(&self) -> u64 {
        self.quota_throttles_by_tenant.iter().sum()
    }

    pub fn violations(&self) -> u64 {
        self.violations_by_tenant.iter().sum()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("enabled", self.enabled.into()),
            ("policy", Json::str(&self.policy)),
            ("quota_throttles", self.quota_throttles().into()),
            ("isolation_violations", self.violations().into()),
            ("takeovers", self.takeovers.into()),
            ("blast_nodes", self.blast_nodes.into()),
            ("blast_pods", self.blast_pods.into()),
            ("blast_innocent_pods", self.blast_innocent_pods.into()),
            ("blast_storage_surfaces", self.blast_storage_surfaces.into()),
            (
                "quota_throttles_by_tenant",
                Json::Arr(
                    self.quota_throttles_by_tenant
                        .iter()
                        .map(|&v| v.into())
                        .collect(),
                ),
            ),
            (
                "violations_by_tenant",
                Json::Arr(self.violations_by_tenant.iter().map(|&v| v.into()).collect()),
            ),
            (
                "takeover_exposed_ms_by_tenant",
                Json::Arr(
                    self.takeover_exposed_ms_by_tenant
                        .iter()
                        .map(|&v| v.into())
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_spec() {
        let cfg =
            IsolationConfig::parse_spec("dedicated,quota:8000x32768,pods:50,limit:250x512")
                .unwrap();
        assert_eq!(cfg.policy, IsolationPolicy::Dedicated);
        let q = cfg.quota.unwrap();
        assert_eq!((q.cpu_m, q.mem_mb, q.pods), (8000, 32768, Some(50)));
        assert_eq!(cfg.limit.unwrap().default, Resources::new(250, 512));
    }

    #[test]
    fn pods_entry_alone_leaves_resources_unbounded() {
        let cfg = IsolationConfig::parse_spec("shared,pods:4").unwrap();
        let q = cfg.quota.unwrap();
        assert_eq!(q.pods, Some(4));
        assert_eq!(q.cpu_m, u64::MAX);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "vip",
            "dedicated,quota:8000",
            "dedicated,quota:axb",
            "shared,pods:many",
            "shared,limit:1x2x3",
            "shared,quota",
            "shared,ns:team-a",
        ] {
            assert!(IsolationConfig::parse_spec(bad).is_err(), "{bad:?} must fail");
        }
    }

    #[test]
    fn dedicated_partitions_nodes_by_weight() {
        let mut s = IsolationState::new(
            IsolationConfig::new(IsolationPolicy::Dedicated),
            8,
        );
        s.set_tenants(&[3, 1]);
        // 8 nodes split 3:1 => 6 and 2, contiguous blocks
        let owners: Vec<Option<u16>> = (0..8).map(|i| s.node_owner(NodeId(i))).collect();
        assert_eq!(owners[..6], vec![Some(0); 6]);
        assert_eq!(owners[6..], vec![Some(1); 2]);
        assert!(s.allows(0, NodeId(2)));
        assert!(!s.allows(0, NodeId(7)));
        assert!(s.allows(SHARED_TENANT, NodeId(7)), "infra tolerates all pools");
    }

    #[test]
    fn shared_policy_owns_no_nodes() {
        let mut s =
            IsolationState::new(IsolationConfig::new(IsolationPolicy::Shared), 4);
        s.set_tenants(&[1, 1]);
        assert_eq!(s.node_owner(NodeId(0)), None);
        assert!(s.allows(1, NodeId(0)));
        assert!(!s.constrains_fetch());
    }

    #[test]
    fn quota_admission_charges_and_releases() {
        let mut cfg = IsolationConfig::new(IsolationPolicy::Shared);
        cfg.quota = Some(ResourceQuota {
            cpu_m: 2000,
            mem_mb: 4096,
            pods: Some(2),
        });
        let mut s = IsolationState::new(cfg, 4);
        let req = Resources::new(1000, 1024);
        assert!(s.admits(0, req));
        s.charge(PodId(0), 0, req);
        s.charge(PodId(1), 0, req);
        assert_eq!(s.used_by(0), (Resources::new(2000, 2048), 2));
        // cpu and pod-count caps both full now
        assert!(!s.admits(0, Resources::new(1, 1)));
        assert!(s.admits(SHARED_TENANT, req), "infra is not namespaced");
        s.release(PodId(0));
        assert_eq!(s.used_by(0), (Resources::new(1000, 1024), 1));
        assert!(s.admits(0, req));
        // double release is a no-op; releasing an uncharged pod too
        s.release(PodId(0));
        s.release(PodId(9));
        assert_eq!(s.used_by(0), (Resources::new(1000, 1024), 1));
    }

    #[test]
    fn violations_count_cross_pool_task_starts() {
        let mut s = IsolationState::new(
            IsolationConfig::new(IsolationPolicy::Dedicated),
            4,
        );
        s.set_tenants(&[1, 1]);
        s.note_task_start(1, NodeId(0)); // tenant 1's task on tenant 0's node
        s.note_task_start(0, NodeId(0)); // in-pool: fine
        assert_eq!(s.stats.violations_by_tenant, vec![0, 1]);
        assert_eq!(s.report().violations(), 1);
    }

    #[test]
    fn report_round_trips_counters() {
        let mut s = IsolationState::new(
            IsolationConfig::new(IsolationPolicy::Sandboxed),
            2,
        );
        s.set_tenants(&[1, 1]);
        s.stats.add_throttle(0);
        s.stats.add_exposure(1, 1500);
        s.stats.takeovers = 1;
        let r = s.report();
        assert!(r.enabled);
        assert_eq!(r.policy, "sandboxed");
        assert_eq!(r.quota_throttles(), 1);
        assert_eq!(r.takeover_exposed_ms_by_tenant, vec![0, 1500]);
        let j = r.to_json().to_string();
        assert!(j.contains("\"takeovers\":1"));
        let off = IsolationReport::default();
        assert!(!off.enabled && off.quota_throttles() == 0);
    }

    #[test]
    fn describe_names_the_knobs() {
        let cfg = IsolationConfig::parse_spec("sandboxed,quota:4000x8192,pods:9").unwrap();
        let d = cfg.describe();
        assert!(d.contains("sandboxed") && d.contains("4000m") && d.contains("pods<=9"));
    }
}
