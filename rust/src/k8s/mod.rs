//! The Kubernetes substrate: nodes, pods, the scheduler (with back-off),
//! and the API-server load model. Built from scratch per DESIGN.md §1 —
//! the paper's findings are control-plane phenomena, so these mechanisms
//! are modeled explicitly.

pub mod api_server;
pub mod isolation;
pub mod node;
pub mod pod;
pub mod resources;
pub mod scheduler;

pub use isolation::{IsolationConfig, IsolationPolicy, IsolationState};
pub use node::{Node, NodeId};
pub use pod::{Payload, Pod, PodId, PodPhase};
pub use resources::{LimitRange, Resources};
pub use scheduler::{Scheduler, SchedulerConfig};
