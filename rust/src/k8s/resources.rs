//! Resource requests/capacity in Kubernetes units: CPU millicores and
//! memory MiB. Scheduling is driven entirely by *requests* (§3.1 of the
//! paper): a pod fits a node iff the node's unallocated capacity covers the
//! pod's requests.

use std::fmt;
use std::ops::{Add, AddAssign, Sub, SubAssign};

/// A resource vector (requests or capacity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default, Hash)]
pub struct Resources {
    /// CPU in millicores (1000 = 1 vCPU).
    pub cpu_m: u64,
    /// Memory in MiB.
    pub mem_mb: u64,
}

impl Resources {
    pub const ZERO: Resources = Resources { cpu_m: 0, mem_mb: 0 };

    pub fn new(cpu_m: u64, mem_mb: u64) -> Self {
        Resources { cpu_m, mem_mb }
    }

    /// 4 vCPU / 16 GiB — the paper's worker node shape (§4.1).
    pub fn paper_node() -> Self {
        Resources::new(4000, 16384)
    }

    /// Does `self` (free capacity) cover `req`?
    pub fn covers(&self, req: &Resources) -> bool {
        self.cpu_m >= req.cpu_m && self.mem_mb >= req.mem_mb
    }

    pub fn saturating_sub(self, rhs: Resources) -> Resources {
        Resources {
            cpu_m: self.cpu_m.saturating_sub(rhs.cpu_m),
            mem_mb: self.mem_mb.saturating_sub(rhs.mem_mb),
        }
    }

    pub fn checked_mul(self, n: u64) -> Resources {
        Resources {
            cpu_m: self.cpu_m * n,
            mem_mb: self.mem_mb * n,
        }
    }
}

/// Namespace LimitRange: the default/minimum request a tenant namespace
/// imposes on its pods. Kubernetes uses it to default containers that
/// request nothing and to floor undersized requests — both collapse to a
/// component-wise maximum with `default` (a zero request becomes exactly
/// the default). Applied at pod creation when the isolation subsystem is
/// active (`--isolation ...,limit:<cpu_m>x<mem_mb>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LimitRange {
    pub default: Resources,
}

impl LimitRange {
    /// Default/floor a pod's requests.
    pub fn apply(&self, req: Resources) -> Resources {
        Resources {
            cpu_m: req.cpu_m.max(self.default.cpu_m),
            mem_mb: req.mem_mb.max(self.default.mem_mb),
        }
    }
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, rhs: Resources) -> Resources {
        Resources {
            cpu_m: self.cpu_m + rhs.cpu_m,
            mem_mb: self.mem_mb + rhs.mem_mb,
        }
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, rhs: Resources) {
        self.cpu_m += rhs.cpu_m;
        self.mem_mb += rhs.mem_mb;
    }
}

impl Sub for Resources {
    type Output = Resources;
    fn sub(self, rhs: Resources) -> Resources {
        Resources {
            cpu_m: self.cpu_m - rhs.cpu_m,
            mem_mb: self.mem_mb - rhs.mem_mb,
        }
    }
}

impl SubAssign for Resources {
    fn sub_assign(&mut self, rhs: Resources) {
        self.cpu_m -= rhs.cpu_m;
        self.mem_mb -= rhs.mem_mb;
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}m/{}Mi", self.cpu_m, self.mem_mb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_requires_both_dimensions() {
        let cap = Resources::new(1000, 1000);
        assert!(cap.covers(&Resources::new(1000, 1000)));
        assert!(cap.covers(&Resources::new(500, 999)));
        assert!(!cap.covers(&Resources::new(1001, 10)));
        assert!(!cap.covers(&Resources::new(10, 1001)));
    }

    #[test]
    fn arithmetic() {
        let a = Resources::new(1000, 2048);
        let b = Resources::new(250, 512);
        assert_eq!(a + b, Resources::new(1250, 2560));
        assert_eq!(a - b, Resources::new(750, 1536));
        assert_eq!(b.saturating_sub(a), Resources::ZERO);
        assert_eq!(b.checked_mul(3), Resources::new(750, 1536));
    }

    #[test]
    fn limit_range_defaults_and_floors() {
        let lr = LimitRange {
            default: Resources::new(250, 512),
        };
        // a zero request takes the namespace default exactly
        assert_eq!(lr.apply(Resources::ZERO), Resources::new(250, 512));
        // undersized requests are floored component-wise
        assert_eq!(lr.apply(Resources::new(100, 2048)), Resources::new(250, 2048));
        // requests above the floor pass through untouched
        assert_eq!(lr.apply(Resources::new(1000, 1024)), Resources::new(1000, 1024));
    }

    #[test]
    fn paper_node_shape() {
        let n = Resources::paper_node();
        assert_eq!(n.cpu_m, 4000);
        assert_eq!(n.mem_mb, 16384);
    }
}
