//! Control-plane (API server) load model.
//!
//! The paper attributes the job model's collapse to "the Kubernetes control
//! plane [being] overwhelmed with an excessive number of Pods being
//! requested" (§4.2). We model the API server as a single-server queue:
//! every mutating request (create Job, create Pod, delete Pod, status
//! update) occupies the server for `service_ms`; requests admitted while
//! the server is busy queue up FIFO. Under a 10k-job parallel stage this
//! produces exactly the creation-latency inflation the paper describes,
//! while staying negligible for the worker-pools model (few requests).

use crate::sim::SimTime;

#[derive(Debug, Clone)]
pub struct ApiServerConfig {
    /// Service time per mutating request.
    pub service_ms: u64,
    /// Base round-trip latency added to every request (network + admission).
    pub base_latency_ms: u64,
}

impl Default for ApiServerConfig {
    fn default() -> Self {
        ApiServerConfig {
            service_ms: 8,
            base_latency_ms: 20,
        }
    }
}

/// The API server queue.
#[derive(Debug)]
pub struct ApiServer {
    pub cfg: ApiServerConfig,
    busy_until: SimTime,
    pub requests_total: u64,
    /// Peak backlog observed (for reports).
    pub max_backlog_ms: u64,
}

impl ApiServer {
    pub fn new(cfg: ApiServerConfig) -> Self {
        ApiServer {
            cfg,
            busy_until: SimTime::ZERO,
            requests_total: 0,
            max_backlog_ms: 0,
        }
    }

    /// Admit a mutating request at `now`; returns the time its effect is
    /// applied (the caller schedules the follow-up event at that time).
    pub fn admit(&mut self, now: SimTime) -> SimTime {
        self.requests_total += 1;
        let start = self.busy_until.max(now);
        let backlog = start.saturating_sub(now).as_millis();
        self.max_backlog_ms = self.max_backlog_ms.max(backlog);
        self.busy_until = start + SimTime::from_millis(self.cfg.service_ms);
        self.busy_until + SimTime::from_millis(self.cfg.base_latency_ms)
    }

    /// Current queueing delay a new request would see.
    pub fn current_backlog(&self, now: SimTime) -> SimTime {
        self.busy_until.saturating_sub(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_server_adds_base_latency_only() {
        let mut api = ApiServer::new(ApiServerConfig {
            service_ms: 10,
            base_latency_ms: 20,
        });
        let done = api.admit(SimTime(1000));
        assert_eq!(done, SimTime(1030));
    }

    #[test]
    fn burst_queues_fifo() {
        let mut api = ApiServer::new(ApiServerConfig {
            service_ms: 10,
            base_latency_ms: 0,
        });
        let d1 = api.admit(SimTime::ZERO);
        let d2 = api.admit(SimTime::ZERO);
        let d3 = api.admit(SimTime::ZERO);
        assert_eq!(
            (d1, d2, d3),
            (SimTime(10), SimTime(20), SimTime(30))
        );
        assert_eq!(api.requests_total, 3);
    }

    #[test]
    fn server_drains_when_idle() {
        let mut api = ApiServer::new(ApiServerConfig {
            service_ms: 10,
            base_latency_ms: 0,
        });
        api.admit(SimTime::ZERO);
        // long gap: server idle again
        let done = api.admit(SimTime(1_000));
        assert_eq!(done, SimTime(1_010));
        assert_eq!(api.current_backlog(SimTime(1_010)), SimTime::ZERO);
    }

    #[test]
    fn backlog_tracks_peak() {
        let mut api = ApiServer::new(ApiServerConfig {
            service_ms: 100,
            base_latency_ms: 0,
        });
        for _ in 0..10 {
            api.admit(SimTime::ZERO);
        }
        // 10th request waited 900ms
        assert_eq!(api.max_backlog_ms, 900);
    }

    #[test]
    fn thousands_of_creations_inflate_latency() {
        // the paper's 16k-job collapse mechanism: API queueing grows linearly
        let mut api = ApiServer::new(ApiServerConfig::default());
        let mut last = SimTime::ZERO;
        for _ in 0..10_000 {
            last = api.admit(SimTime::ZERO);
        }
        assert!(last.as_secs_f64() > 60.0, "10k requests should take >1min");
    }
}
