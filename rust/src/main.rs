//! `hyperflow` CLI: run simulated experiments from the command line.

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::util::cli::Args;
use hyperflow_k8s::util::{ascii_plot, logger};
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn usage() -> ! {
    eprintln!(
        "usage: hyperflow <command> [flags]\n\
         commands:\n\
           run        --model job|clustered|pools|generic-pool [--tasks N] [--nodes N] [--seed S]\n\
           run        --config configs/<name>.json   (full experiment description)\n\
           generate   --tasks N --out wf.json\n\
           info       --tasks N\n\
         flags for run:\n\
           --cluster-size N --cluster-timeout MS   (clustered model)\n\
           --max-pending N                          (throttled job model, §5)\n\
           --json                                   print result as JSON\n\
           --html FILE                              write an HTML report\n"
    );
    std::process::exit(2)
}

fn main() {
    logger::init();
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("run") => cmd_run(&args),
        Some("generate") => cmd_generate(&args),
        Some("info") => cmd_info(&args),
        Some("trace") => cmd_trace(&args),
        _ => usage(),
    }
}

/// `hyperflow trace --model pools --tasks 2000 --out trace.json` — export a
/// Chrome trace-event file (open in chrome://tracing or Perfetto).
fn cmd_trace(args: &Args) {
    let cfg = montage_cfg(args);
    let dag = generate(&cfg);
    let model = match args.get_or("model", "pools") {
        "job" => ExecModel::JobBased,
        "clustered" => ExecModel::Clustered(ClusteringConfig::paper_default()),
        _ => ExecModel::paper_hybrid_pools(),
    };
    let res = driver::run(
        dag,
        model,
        driver::SimConfig::with_nodes(args.get_usize("nodes", 17)),
    );
    let out = args.get_or("out", "trace.json");
    std::fs::write(out, hyperflow_k8s::report::chrome::to_chrome_trace(&res).to_string())
        .expect("write trace");
    eprintln!(
        "wrote {out} ({} tasks, makespan {:.0}s) — open in chrome://tracing",
        res.trace.records.len(),
        res.makespan.as_secs_f64()
    );
}

fn montage_cfg(args: &Args) -> MontageConfig {
    let tasks = args.get_usize("tasks", 16_000);
    let seed = args.get_u64("seed", 42);
    MontageConfig::with_total_tasks(tasks, seed)
}

fn cmd_run(args: &Args) {
    // config-file mode: the whole experiment comes from JSON
    let res = if let Some(path) = args.get("config") {
        let exp = hyperflow_k8s::config::ExperimentConfig::load(path)
            .unwrap_or_else(|e| {
                eprintln!("config error: {e:#}");
                std::process::exit(1)
            });
        eprintln!("running experiment '{}' ({})", exp.name, exp.model.name());
        exp.run().unwrap_or_else(|e| {
            eprintln!("run error: {e:#}");
            std::process::exit(1)
        })
    } else {
        let cfg = montage_cfg(args);
        let dag = generate(&cfg);
        let model = match args.get_or("model", "pools") {
            "job" | "job-based" => ExecModel::JobBased,
            "clustered" => {
                let size = args.get_usize("cluster-size", 0);
                let c = if size > 0 {
                    ClusteringConfig::uniform(size, args.get_u64("cluster-timeout", 3000))
                } else {
                    ClusteringConfig::paper_default()
                };
                ExecModel::Clustered(c)
            }
            "pools" | "worker-pools" => ExecModel::paper_hybrid_pools(),
            "generic-pool" | "generic" => ExecModel::GenericPool,
            m => {
                eprintln!("unknown model '{m}'");
                usage()
            }
        };
        let mut sim = driver::SimConfig::with_nodes(args.get_usize("nodes", 17));
        if args.has("max-pending") {
            sim.max_pending_pods = Some(args.get_usize("max-pending", 64));
        }
        let n_tasks = dag.len();
        eprintln!(
            "running {} on montage {}x{} ({} tasks), {} nodes",
            model.name(),
            cfg.grid_w,
            cfg.grid_h,
            n_tasks,
            sim.nodes
        );
        driver::run(dag, model, sim)
    };
    if let Some(path) = args.get("html") {
        let html = hyperflow_k8s::report::html::render(&res);
        std::fs::write(path, html).expect("write html report");
        eprintln!("wrote {path}");
    }
    if args.has("json") {
        println!("{}", res.to_json());
    } else {
        println!(
            "makespan: {:.0}s  pods: {}  api-requests: {}  backoffs: {}",
            res.makespan.as_secs_f64(),
            res.pods_created,
            res.api_requests,
            res.sched_backoffs
        );
        println!(
            "avg running tasks: {:.1}   avg cpu utilization: {:.1}%",
            res.avg_running_tasks,
            res.avg_cpu_utilization * 100.0
        );
        println!(
            "{}",
            ascii_plot::area_chart(
                "cluster utilization (running tasks)",
                &res.running_series(),
                100,
                12
            )
        );
    }
}

fn cmd_generate(args: &Args) {
    let cfg = montage_cfg(args);
    let dag = generate(&cfg);
    let out = args.get_or("out", "wf.json");
    hyperflow_k8s::workflow::wfjson::save(&dag, out).expect("write workflow");
    eprintln!("wrote {} tasks to {out}", dag.len());
}

fn cmd_info(args: &Args) {
    let cfg = montage_cfg(args);
    let dag = generate(&cfg);
    println!("workflow: {}", dag.name());
    println!("tasks: {}", dag.len());
    for (ty, n) in dag.count_by_type() {
        println!("  {ty:>12}: {n}");
    }
    println!("critical path: {:.0}s", dag.critical_path_secs());
    let total_work: f64 = dag.work_by_type().values().sum();
    println!("total work: {total_work:.0} core-seconds");
}
