//! `hyperflow` CLI: run simulated experiments from the command line.

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::util::cli::Args;
use hyperflow_k8s::util::{ascii_plot, logger};
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn usage() -> ! {
    eprintln!(
        "usage: hyperflow <command> [flags]\n\
         commands:\n\
           run        --model job|clustered|pools|generic-pool [--tasks N] [--nodes N] [--seed S]\n\
           run        --config configs/<name>.json   (full experiment description)\n\
           serve      --arrival-rate R --duration S --tenants K --model ... [--seed S]\n\
           generate   --tasks N --out wf.json\n\
           info       --tasks N\n\
           trace      --model job|clustered|pools --tasks N --out trace.json\n\
                      (Chrome trace-event export for chrome://tracing / Perfetto)\n\
           diff       A.json B.json [--json] [--html FILE]\n\
                      (differential analysis of two --snapshot files: the\n\
                      makespan delta decomposed phase-by-phase — deltas sum\n\
                      exactly to the makespan delta — first critical-path\n\
                      divergence, counter/gauge/alert/tenant changes)\n\
           diff       --bench BASELINE.json CURRENT.json [--tolerance FILE]\n\
                      (perf-regression gate over two BENCH_*.json artifacts;\n\
                      exits 1 when a metric moves beyond its tolerance,\n\
                      skips with a notice on placeholder baselines)\n\
         flags for run:\n\
           --cluster-size N --cluster-timeout MS   (clustered model)\n\
           --max-pending N                          (throttled job model, §5)\n\
           --chaos SPEC                             failure injection (see below)\n\
           --data SPEC                              storage/transfer modeling (see below)\n\
           --isolation SPEC                         tenant isolation (see below)\n\
           --obs SPEC                               flight recorder (see below)\n\
           --monitor SPEC                           in-sim monitoring stack (see below)\n\
           --json                                   print result as JSON\n\
           --html FILE                              write an HTML report\n\
           --snapshot FILE                          write a versioned run snapshot\n\
                      (deterministic: same seed => byte-identical file; feed a\n\
                      pair of them to `hyperflow diff`; also on serve/trace)\n\
         obs SPEC (run/serve/trace): flight recorder, comma-separated\n\
           trace:FILE   extended Chrome trace: control-plane instant events,\n\
                        counter tracks, per-node pod lanes (Perfetto-ready)\n\
           prom:FILE    Prometheus/OpenMetrics text exposition of every\n\
                        counter and gauge at end of run\n\
           crit:on      print the critical-path attribution report\n\
                        (makespan decomposed into queueing / scheduling /\n\
                        pod-start / stage-in / compute / stage-out / recovery)\n\
           bare --obs enables recording only (attribution still lands in\n\
           --json/--html); recording never perturbs the simulation\n\
           e.g. --obs trace:out.json,prom:metrics.txt,crit:on\n\
         monitor SPEC (run/serve/trace): deterministic scrape loop with\n\
           recording rules and SLO burn-rate alerting, comma-separated\n\
           interval:S   scrape interval in sim seconds (default 30)\n\
           rules:X      builtin (default) or a rule file: record / alert\n\
                        (threshold + for: duration) / burnrate statements\n\
                        over rate(), increase(), avg/max/min_over_time(),\n\
                        changes(), ewma(), holt_winters()\n\
           alerts:FILE  write the alert report (lifecycles, episodes,\n\
                        final recording-rule values) as JSON\n\
           bare --monitor = interval:30,rules:builtin; scrapes only read\n\
           kernel state (RNG-free fixed ticks) so the simulated trace is\n\
           unchanged; alert states also land in --json/--html and in the\n\
           --obs prom exposition as ALERTS{{...}} series\n\
           e.g. --monitor interval:15,rules:builtin,alerts:alerts.json\n\
         chaos SPEC (run/serve/trace): comma-separated kind:value\n\
           spot:R       spot reclaims per node per hour (2 min warning)\n\
           crash:R      node crashes per node per hour (no warning)\n\
           pod:P        pod crash probability at container start\n\
           straggler:F  fraction of nodes running tasks 3x slower\n\
           takeover:T@S tenant T compromised at S seconds (blast radius is\n\
                        measured against the --isolation policy, then drained)\n\
           e.g. --chaos spot:0.2,crash:0.1,straggler:0.25 --seed 7\n\
         isolation SPEC (run/serve): policy[,quota:...][,pods:N][,limit:...]\n\
           shared|dedicated|sandboxed   node-pool policy: shared nodes,\n\
                        per-tenant node partitions, or partitions plus a\n\
                        sandbox runtime (no node escape, slower pod start)\n\
           quota:CxM    per-tenant ResourceQuota, C milli-CPU x M MiB\n\
           pods:N       per-tenant pod-count quota\n\
           limit:CxM    LimitRange floor applied to pod requests\n\
           e.g. --isolation dedicated,quota:16000x65536,pods:64\n\
         data SPEC (run/serve/trace): comma-separated kind:value\n\
           nfs:G        shared NFS backend, G Gbit/s aggregate server bandwidth\n\
           s3:LxG       object store, L ms request latency, G Gbit/s per stream\n\
           cache:GB     node-local ephemeral cache per node (decimal GB, default 8)\n\
           locality:on  schedule pods onto nodes already caching their inputs\n\
           exactly one backend (nfs or s3) is required; stage-in runs before\n\
           and stage-out after every task; pool workers keep warm caches,\n\
           job pods always start cold\n\
           e.g. --data nfs:1,cache:8,locality:on   or   --data s3:30x1.5,cache:4\n\
         flags for serve (open-loop multi-tenant fleet):\n\
           --arrival-rate R    aggregate arrivals in instances/hour (default 6)\n\
           --duration S        arrival window in seconds (default 3600)\n\
           --tenants K         tenant count (default 2)\n\
           --model M           job|clustered|pools|generic-pool (default pools)\n\
           --nodes N           cluster size (default 17)\n\
           --seed S            master seed: arrivals, sizes, durations (default 42)\n\
           --process poisson|burst [--burst-every S] [--burst-size N]\n\
           --grids 4,5,6       Montage grid-size mix spread across tenants\n\
           --weights 2,1       fair-share dequeue weight per tenant\n\
           --cap N             admission cap: max concurrent instances (0 = off)\n\
           --chaos SPEC        failure injection during the fleet run\n\
           --isolation SPEC    tenant isolation during the fleet run\n\
           --obs SPEC          flight recorder; adds per-tenant crit-* columns\n\
           --monitor SPEC      monitoring stack; adds per-tenant alert columns\n\
           --json              print the fleet report as JSON\n\
         validation: flag combinations are checked up front and exit with a\n\
           named config error (e.g. zero nodes, empty/duplicate pool set,\n\
           node event outside the cluster, --weights arity mismatch)\n"
    );
    std::process::exit(2)
}

/// Build a validated `SimConfig` from the shared CLI flags; a bad
/// combination exits with the named [`hyperflow_k8s::exec::ConfigError`]
/// instead of panicking mid-run.
fn parse_sim(args: &Args, max_pending: bool) -> driver::SimConfig {
    let mut b = driver::SimConfig::builder()
        .nodes(args.get_usize("nodes", 17))
        .seed(args.get_u64("seed", 42))
        .chaos(parse_chaos(args))
        .data(parse_data(args))
        .isolation(parse_isolation(args))
        // --snapshot needs the flight recorder for attribution and the
        // critical path, so it implies recording (which never perturbs)
        .obs(args.has("obs") || args.has("snapshot"))
        .monitor(parse_monitor(args));
    if max_pending && args.has("max-pending") {
        b = b.max_pending_pods(Some(args.get_usize("max-pending", 64)));
    }
    b.build().unwrap_or_else(|e| {
        eprintln!("config error: {e}");
        usage()
    })
}

fn main() {
    logger::init();
    let args = Args::from_env();
    match args.positional.first().map(String::as_str) {
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("info") => cmd_info(&args),
        Some("trace") => cmd_trace(&args),
        Some("diff") => cmd_diff(&args),
        _ => usage(),
    }
}

/// Shared `--chaos` spec parsing for `run` / `serve` / `trace`.
fn parse_chaos(args: &Args) -> hyperflow_k8s::chaos::ChaosConfig {
    match args.get("chaos") {
        None => hyperflow_k8s::chaos::ChaosConfig::default(),
        Some(spec) => hyperflow_k8s::chaos::ChaosConfig::parse_spec(spec).unwrap_or_else(|e| {
            eprintln!("--chaos: {e}");
            usage()
        }),
    }
}

/// Shared `--data` spec parsing for `run` / `serve` / `trace`: a malformed
/// spec exits with the named parse error instead of panicking.
fn parse_data(args: &Args) -> Option<hyperflow_k8s::data::DataConfig> {
    args.get("data").map(|spec| {
        hyperflow_k8s::data::DataConfig::parse_spec(spec).unwrap_or_else(|e| {
            eprintln!("--data: {e}");
            usage()
        })
    })
}

/// Shared `--isolation` spec parsing for `run` / `serve`: a malformed
/// spec exits with the named parse error instead of panicking.
fn parse_isolation(args: &Args) -> Option<hyperflow_k8s::k8s::isolation::IsolationConfig> {
    args.get("isolation").map(|spec| {
        hyperflow_k8s::k8s::isolation::IsolationConfig::parse_spec(spec).unwrap_or_else(|e| {
            eprintln!("--isolation: {e}");
            usage()
        })
    })
}

/// Shared `--obs` spec parsing for `run` / `serve` / `trace`. A bare
/// `--obs` enables recording without exporting any files.
fn parse_obs(args: &Args) -> Option<hyperflow_k8s::obs::ObsSpec> {
    args.get("obs").map(|spec| {
        if spec == "true" {
            // bare flag: the CLI parser stores "true" for valueless flags
            return hyperflow_k8s::obs::ObsSpec::default();
        }
        hyperflow_k8s::obs::ObsSpec::parse_spec(spec).unwrap_or_else(|e| {
            eprintln!("--obs: {e}");
            usage()
        })
    })
}

/// Shared `--monitor` spec parsing for `run` / `serve` / `trace`. A bare
/// `--monitor` takes every default (30 s scrapes, builtin rules). A
/// `rules:FILE` entry is loaded here — the library stays
/// filesystem-free and validates the text at config-build time.
fn parse_monitor(args: &Args) -> Option<hyperflow_k8s::obs::monitor::MonitorConfig> {
    args.get("monitor").map(|spec| {
        let (mut cfg, rules_path) =
            hyperflow_k8s::obs::monitor::MonitorConfig::parse_spec(spec).unwrap_or_else(|e| {
                eprintln!("--monitor: {e}");
                usage()
            });
        if let Some(path) = rules_path {
            let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
                eprintln!("--monitor: cannot read rules file '{path}': {e}");
                usage()
            });
            cfg.rules = hyperflow_k8s::obs::monitor::RulesSource::Inline(text);
        }
        cfg
    })
}

/// End-of-run monitoring output: the alert summary and timeline on
/// stderr, plus the `alerts:FILE` JSON artifact when requested.
fn write_monitor_artifacts(res: &hyperflow_k8s::report::SimResult, args: &Args) {
    let Some(mon) = &res.monitor else { return };
    eprintln!(
        "monitor: {} scrapes @ {:.0}s   alerts fired: {}   time firing: {:.0}s",
        mon.ticks,
        mon.interval_ms as f64 / 1000.0,
        mon.fired_total(),
        mon.firing_ms_total() as f64 / 1000.0,
    );
    for (ms, line) in mon.timeline() {
        eprintln!("  [{:>8.1}s] {line}", ms as f64 / 1000.0);
    }
    // the alerts path rides on the spec string; re-parsing it here is
    // cheap and keeps SimConfig the single owner of the monitor config
    let alerts_out = args
        .get("monitor")
        .and_then(|spec| hyperflow_k8s::obs::monitor::MonitorConfig::parse_spec(spec).ok())
        .and_then(|(cfg, _)| cfg.alerts_out);
    if let Some(path) = alerts_out {
        std::fs::write(&path, format!("{}\n", mon.to_json())).expect("write alerts json");
        eprintln!("wrote {path}");
    }
}

/// Write the `--obs` artifacts for a finished run: extended Chrome trace,
/// Prometheus text exposition, and (with `crit:on`) the attribution
/// report on stderr.
fn write_obs_artifacts(res: &hyperflow_k8s::report::SimResult, spec: &hyperflow_k8s::obs::ObsSpec) {
    if let Some(path) = &spec.trace_out {
        std::fs::write(
            path,
            hyperflow_k8s::report::chrome::to_chrome_trace(res).to_string(),
        )
        .expect("write obs trace");
        eprintln!("wrote {path}");
    }
    if let Some(path) = &spec.prom_out {
        std::fs::write(
            path,
            hyperflow_k8s::obs::prom::render_with_alerts(&res.metrics, res.monitor.as_ref()),
        )
        .expect("write prom exposition");
        eprintln!("wrote {path}");
    }
    if spec.crit {
        match res.obs.as_ref().and_then(|o| o.attribution.as_ref()) {
            Some(a) => eprint!("{}", a.render(res.makespan)),
            None => eprintln!("note: no critical-path attribution available"),
        }
    }
}

/// Shared `--model` parsing for `run` / `serve` / `trace`.
fn parse_model(args: &Args) -> ExecModel {
    let model = match args.get_or("model", "pools") {
        "job" | "job-based" => ExecModel::JobBased,
        "clustered" => {
            let size = args.get_usize("cluster-size", 0);
            if size > 0 {
                ExecModel::Clustered(ClusteringConfig::uniform(
                    size,
                    args.get_u64("cluster-timeout", 3000),
                ))
            } else {
                ExecModel::Clustered(ClusteringConfig::paper_default())
            }
        }
        "pools" | "worker-pools" => ExecModel::paper_hybrid_pools(),
        "generic-pool" | "generic" => ExecModel::GenericPool,
        m => {
            eprintln!("unknown model '{m}'");
            usage()
        }
    };
    if let Err(e) = model.validate() {
        eprintln!("config error: {e}");
        usage()
    }
    model
}

/// `hyperflow trace --model pools --tasks 2000 --out trace.json` — export a
/// Chrome trace-event file (open in chrome://tracing or Perfetto).
/// Write the `--snapshot FILE` artifact: deterministic, versioned run
/// snapshot JSON for `hyperflow diff`.
fn write_snapshot(path: &str, snap: hyperflow_k8s::util::json::Json) {
    std::fs::write(path, format!("{snap}\n")).expect("write snapshot");
    eprintln!("wrote {path}");
}

fn cmd_trace(args: &Args) {
    let cfg = montage_cfg(args);
    let dag = generate(&cfg);
    let model = parse_model(args);
    let sim = parse_sim(args, false);
    // keep the config for snapshot provenance (run() consumes it)
    let snap_cfg = sim.clone();
    let res = driver::run(dag, model, sim);
    let out = args.get_or("out", "trace.json");
    std::fs::write(out, hyperflow_k8s::report::chrome::to_chrome_trace(&res).to_string())
        .expect("write trace");
    eprintln!(
        "wrote {out} ({} tasks, makespan {:.0}s) — open in chrome://tracing",
        res.trace.records.len(),
        res.makespan.as_secs_f64()
    );
    // `--obs` enables the recorder (extended tracks land in the trace
    // above); prom/crit entries export their artifacts on top
    if let Some(spec) = parse_obs(args) {
        write_obs_artifacts(&res, &spec);
    }
    write_monitor_artifacts(&res, args);
    if let Some(path) = args.get("snapshot") {
        write_snapshot(path, hyperflow_k8s::obs::snapshot::capture(&res, &snap_cfg));
    }
}

fn montage_cfg(args: &Args) -> MontageConfig {
    let tasks = args.get_usize("tasks", 16_000);
    let seed = args.get_u64("seed", 42);
    MontageConfig::with_total_tasks(tasks, seed)
}

fn cmd_run(args: &Args) {
    // set in flag mode only: snapshots need the SimConfig for provenance
    let mut snap_cfg: Option<driver::SimConfig> = None;
    // config-file mode: the whole experiment comes from JSON
    let res = if let Some(path) = args.get("config") {
        let exp = hyperflow_k8s::config::ExperimentConfig::load(path)
            .unwrap_or_else(|e| {
                eprintln!("config error: {e:#}");
                std::process::exit(1)
            });
        eprintln!("running experiment '{}' ({})", exp.name, exp.model.name());
        exp.run().unwrap_or_else(|e| {
            eprintln!("run error: {e:#}");
            std::process::exit(1)
        })
    } else {
        let cfg = montage_cfg(args);
        let dag = generate(&cfg);
        let model = parse_model(args);
        let sim = parse_sim(args, true);
        let n_tasks = dag.len();
        eprintln!(
            "running {} on montage {}x{} ({} tasks), {} nodes",
            model.name(),
            cfg.grid_w,
            cfg.grid_h,
            n_tasks,
            sim.nodes
        );
        snap_cfg = Some(sim.clone());
        driver::run(dag, model, sim)
    };
    if let Some(path) = args.get("snapshot") {
        match &snap_cfg {
            Some(cfg) => write_snapshot(path, hyperflow_k8s::obs::snapshot::capture(&res, cfg)),
            None => eprintln!("note: --snapshot is not supported with --config"),
        }
    }
    if let Some(path) = args.get("html") {
        let html = hyperflow_k8s::report::html::render(&res);
        std::fs::write(path, html).expect("write html report");
        eprintln!("wrote {path}");
    }
    if let Some(spec) = parse_obs(args) {
        write_obs_artifacts(&res, &spec);
    }
    write_monitor_artifacts(&res, args);
    if args.has("json") {
        println!("{}", res.to_json());
    } else {
        println!(
            "makespan: {:.0}s  pods: {}  api-requests: {}  backoffs: {}",
            res.makespan.as_secs_f64(),
            res.pods_created,
            res.api_requests,
            res.sched_backoffs
        );
        println!(
            "avg running tasks: {:.1}   avg cpu utilization: {:.1}%",
            res.avg_running_tasks,
            res.avg_cpu_utilization * 100.0
        );
        if res.chaos.enabled {
            println!(
                "chaos: {} faults (pod {}, reclaim {}, crash {})  retries: {}  \
                 speculative: {}  wasted: {:.0}s  goodput: {:.1}%  recovery p95: {:.1}s",
                res.chaos.faults_total(),
                res.chaos.pod_failures,
                res.chaos.spot_reclaims,
                res.chaos.node_crashes,
                res.chaos.retries,
                res.chaos.speculations,
                res.chaos.wasted_ms as f64 / 1000.0,
                res.chaos.goodput() * 100.0,
                res.chaos.recovery_p95_s,
            );
        }
        if res.data.enabled {
            println!(
                "data: {:.2} GB moved ({:.2} in / {:.2} out)  cache hits: {:.1}%  \
                 stage-in p50/p95/p99: {:.2}/{:.2}/{:.2}s  I/O share: {:.1}%",
                res.data.bytes_moved() as f64 / 1e9,
                res.data.bytes_in as f64 / 1e9,
                res.data.bytes_out as f64 / 1e9,
                res.data.cache_hit_ratio() * 100.0,
                res.data.stage_in_p50_s,
                res.data.stage_in_p95_s,
                res.data.stage_in_p99_s,
                res.data.io_frac() * 100.0,
            );
        }
        if res.isolation.enabled {
            println!(
                "isolation: policy {}  quota throttles: {}  violations: {}  \
                 takeovers: {} (blast: {} nodes, {} pods, {} innocent)",
                res.isolation.policy,
                res.isolation.quota_throttles(),
                res.isolation.violations(),
                res.isolation.takeovers,
                res.isolation.blast_nodes,
                res.isolation.blast_pods,
                res.isolation.blast_innocent_pods,
            );
        }
        if let Some(mon) = &res.monitor {
            println!(
                "monitor: {} scrapes   alerts fired: {}   time firing: {:.0}s",
                mon.ticks,
                mon.fired_total(),
                mon.firing_ms_total() as f64 / 1000.0,
            );
        }
        println!(
            "{}",
            ascii_plot::area_chart(
                "cluster utilization (running tasks)",
                &res.running_series(),
                100,
                12
            )
        );
    }
}

/// Parse a comma-separated numeric flag value.
fn parse_list<T: std::str::FromStr>(raw: &str, flag: &str) -> Vec<T> {
    raw.split(',')
        .map(|v| {
            v.trim().parse().unwrap_or_else(|_| {
                eprintln!("--{flag}: '{v}' is not a number");
                usage()
            })
        })
        .collect()
}

/// `hyperflow serve` — the fleet service: open-loop multi-tenant workflow
/// arrivals executed concurrently on one shared simulated cluster, with
/// weighted fair-share scheduling and a per-tenant SLO report.
fn cmd_serve(args: &Args) {
    use hyperflow_k8s::fleet::{self, ArrivalProcess, FleetConfig};

    let rate = args.get_f64("arrival-rate", 6.0);
    let duration = args.get_f64("duration", 3600.0);
    let n_tenants = args.get_usize("tenants", 2);
    if n_tenants == 0 {
        eprintln!("--tenants must be at least 1");
        usage()
    }
    let nodes = args.get_usize("nodes", 17);
    let seed = args.get_u64("seed", 42);
    let model = parse_model(args);
    let grids: Vec<usize> = args
        .get("grids")
        .map(|s| parse_list(s, "grids"))
        .unwrap_or_else(|| vec![4, 5, 6]);
    let mut tenants = fleet::default_tenants(n_tenants, &grids);
    if let Some(w) = args.get("weights") {
        let ws: Vec<u64> = parse_list(w, "weights");
        if ws.len() != n_tenants {
            eprintln!("--weights must list exactly one weight per tenant");
            usage()
        }
        if ws.iter().any(|&w| w == 0 || w > (1 << 20)) {
            eprintln!("--weights entries must be in 1..={}", 1u64 << 20);
            usage()
        }
        for (t, w) in tenants.iter_mut().zip(ws) {
            t.weight = w;
        }
        // fair-share lives in the worker-pool queue lanes: warn when the
        // chosen model routes tasks where the weights cannot act
        let uniform = tenants.iter().all(|t| t.weight == tenants[0].weight);
        if !uniform {
            match &model {
                ExecModel::JobBased | ExecModel::Clustered(_) => eprintln!(
                    "warning: --weights has no effect under the {} model \
                     (fair-share applies only to worker-pool queues)",
                    model.name()
                ),
                ExecModel::WorkerPools { .. } => eprintln!(
                    "note: fair-share weights govern the pooled parallel stages; \
                     serial stages run as jobs outside fair-share"
                ),
                ExecModel::GenericPool => {}
            }
        }
    }
    let arrival = match args.get_or("process", "poisson") {
        "poisson" => {
            if rate <= 0.0 {
                eprintln!("--arrival-rate must be positive");
                usage()
            }
            ArrivalProcess::Poisson { per_hour: rate }
        }
        "burst" => ArrivalProcess::Burst {
            every_s: args.get_f64("burst-every", 600.0),
            size: args.get_usize("burst-size", 4),
        },
        p => {
            eprintln!("unknown arrival process '{p}'");
            usage()
        }
    };
    let cap = args.get_usize("cap", 0);
    let fleet_cfg = FleetConfig {
        arrival,
        duration_s: duration,
        tenants,
        seed,
        max_in_flight: (cap > 0).then_some(cap),
    };
    let sim = parse_sim(args, false);
    let snap_cfg = sim.clone();
    eprintln!(
        "fleet: {} arrivals over {duration:.0}s, {n_tenants} tenants, {} on {nodes} nodes (seed {seed})",
        fleet_cfg.arrival.label(),
        model.name(),
    );
    let res = fleet::run(model, sim, &fleet_cfg);
    if res.outcomes.is_empty() {
        eprintln!(
            "note: the arrival process produced no instances in the window — \
             raise --arrival-rate or --duration"
        );
    }
    if let Some(spec) = parse_obs(args) {
        write_obs_artifacts(&res.sim, &spec);
    }
    write_monitor_artifacts(&res.sim, args);
    if let Some(path) = args.get("snapshot") {
        write_snapshot(
            path,
            hyperflow_k8s::obs::snapshot::capture_fleet(&res, &snap_cfg),
        );
    }
    if args.has("json") {
        println!("{}", fleet::report::to_json(&res));
    } else {
        let agg = fleet::report::aggregate(&res);
        println!(
            "instances: {}   span: {:.0}s   throughput: {:.1}/h   utilization: {:.1}%",
            agg.instances,
            agg.span_s,
            agg.completed_per_hour,
            agg.utilization * 100.0
        );
        println!(
            "queueing delay (mean): {:.1}s   slowdown mean: {:.2}   slowdown p99: {:.2}",
            agg.mean_queue_delay_s, agg.mean_slowdown, agg.slowdown_p99
        );
        if res.sim.chaos.enabled {
            println!(
                "chaos: {} faults   retries: {}   wasted: {:.0}s   goodput: {:.1}%",
                res.sim.chaos.faults_total(),
                res.sim.chaos.retries,
                res.sim.chaos.wasted_ms as f64 / 1000.0,
                res.sim.chaos.goodput() * 100.0
            );
        }
        if res.sim.data.enabled {
            println!(
                "data: {:.2} GB moved   cache hits: {:.1}%   stage-in p95: {:.2}s",
                res.sim.data.bytes_moved() as f64 / 1e9,
                res.sim.data.cache_hit_ratio() * 100.0,
                res.sim.data.stage_in_p95_s
            );
        }
        if res.sim.isolation.enabled {
            println!(
                "isolation: policy {}   quota throttles: {}   violations: {}   takeovers: {}",
                res.sim.isolation.policy,
                res.sim.isolation.quota_throttles(),
                res.sim.isolation.violations(),
                res.sim.isolation.takeovers
            );
        }
        println!();
        print!("{}", fleet::report::render_table(&res));
    }
}

/// `hyperflow diff A.json B.json` — differential analysis of two run
/// snapshots — and `hyperflow diff --bench BASE.json CUR.json` — the
/// perf-regression gate over two `BENCH_*.json` artifacts.
///
/// Exit codes: 0 = compared (diff printed / gate passed or skipped),
/// 1 = gate breached, 2 = unreadable or malformed input.
fn cmd_diff(args: &Args) {
    use hyperflow_k8s::obs::diff::{compare_bench, diff, Tolerances};
    use hyperflow_k8s::report::diff::{render_bench_text, render_html, render_text};
    use hyperflow_k8s::util::json::Json;

    let mut files: Vec<String> = Vec::new();
    if let Some(v) = args.get("bench") {
        // the CLI parser consumes the token after `--bench` as the
        // flag's value; recover it as the first input file
        if v != "true" {
            files.push(v.to_string());
        }
    }
    files.extend(args.positional.iter().skip(1).cloned());
    let [a_path, b_path] = files.as_slice() else {
        eprintln!("diff: expected exactly two input files, got {}", files.len());
        usage()
    };
    let read = |path: &str| -> Json {
        let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("diff: cannot read '{path}': {e}");
            std::process::exit(2)
        });
        Json::parse(&text).unwrap_or_else(|e| {
            eprintln!("diff: '{path}' is not valid JSON: {e}");
            std::process::exit(2)
        })
    };
    let a = read(a_path);
    let b = read(b_path);

    if args.has("bench") {
        let tol = match args.get("tolerance") {
            Some(path) if path != "true" => Tolerances::parse(&read(path)).unwrap_or_else(|e| {
                eprintln!("diff: --tolerance: {e}");
                std::process::exit(2)
            }),
            _ => Tolerances::default(),
        };
        let outcome = compare_bench(&a, &b, &tol);
        print!("{}", render_bench_text(a_path, b_path, &outcome));
        if outcome.breached() {
            std::process::exit(1);
        }
        return;
    }

    let d = diff(&a, &b).unwrap_or_else(|e| {
        eprintln!("diff: {e}");
        std::process::exit(2)
    });
    if let Some(path) = args.get("html") {
        std::fs::write(path, render_html(&d)).expect("write diff html");
        eprintln!("wrote {path}");
    }
    if args.has("json") {
        println!("{}", d.to_json());
    } else {
        print!("{}", render_text(&d));
    }
}

fn cmd_generate(args: &Args) {
    let cfg = montage_cfg(args);
    let dag = generate(&cfg);
    let out = args.get_or("out", "wf.json");
    hyperflow_k8s::workflow::wfjson::save(&dag, out).expect("write workflow");
    eprintln!("wrote {} tasks to {out}", dag.len());
}

fn cmd_info(args: &Args) {
    let cfg = montage_cfg(args);
    let dag = generate(&cfg);
    println!("workflow: {}", dag.name());
    println!("tasks: {}", dag.len());
    for (ty, n) in dag.count_by_type() {
        println!("  {ty:>12}: {n}");
    }
    println!("critical path: {:.0}s", dag.critical_path_secs());
    let total_work: f64 = dag.work_by_type().values().sum();
    println!("total work: {total_work:.0} core-seconds");
}
