//! Real-time execution: the workflow runs on wall-clock time with worker
//! "pods" as OS threads executing the *actual* Montage numerics through the
//! PJRT runtime. This is the end-to-end path exercised by
//! `examples/montage_e2e.rs`.
//!
//! Fidelity mapping (scaled-down constants, configurable):
//!   pod creation        -> thread spawn + `pod_start_ms` sleep + artifact
//!                          compile (the real container-start cost!)
//!   worker pool         -> threads consuming a per-type queue (Condvar)
//!   KEDA autoscaler     -> scaler thread polling backlogs every `poll_ms`,
//!                          proportional allocation under a worker quota,
//!                          scale-to-zero via idle timeout
//!   job model           -> one thread per task, paying the full pod
//!                          start + artifact load each time
//!
//! Python never runs here: the artifacts were AOT-compiled by
//! `make artifacts`.

use crate::compute::{MontageCompute, VerifyReport};
use crate::engine::Engine;
use crate::runtime::Runtime;
use crate::util::stats::Summary;
use crate::workflow::dag::Dag;
use crate::workflow::montage::{generate, MontageConfig};
use crate::workflow::task::TaskId;
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Which execution model the real-time runner uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RealModel {
    /// Hybrid worker pools (pools for the parallel stages, job threads for
    /// the serial tail) — the paper's §4.4 configuration.
    WorkerPools,
    /// One pod (thread) per task.
    Jobs,
}

#[derive(Debug, Clone)]
pub struct RealtimeConfig {
    pub grid: usize,
    pub artifacts_dir: PathBuf,
    /// Simulated container-start latency per pod (the paper's ~2 s, scaled
    /// down by default so the example finishes quickly).
    pub pod_start_ms: u64,
    /// Autoscaler poll interval.
    pub poll_ms: u64,
    /// Worker idle timeout (scale-to-zero).
    pub idle_timeout_ms: u64,
    /// Total worker quota across pools (the "cluster size").
    pub max_workers: usize,
    pub model: RealModel,
    pub seed: u64,
    /// Apply sub-pixel pointing offsets (exercises bilinear reprojection).
    pub warp: bool,
}

impl Default for RealtimeConfig {
    fn default() -> Self {
        RealtimeConfig {
            grid: 4,
            artifacts_dir: PathBuf::from("artifacts"),
            pod_start_ms: 250,
            poll_ms: 100,
            idle_timeout_ms: 600,
            max_workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            model: RealModel::WorkerPools,
            seed: 42,
            warp: true,
        }
    }
}

/// Per-task wall-clock record.
#[derive(Debug, Clone)]
pub struct RtTaskRecord {
    pub type_name: String,
    pub ready_ms: u64,
    pub started_ms: u64,
    pub finished_ms: u64,
}

/// Outcome of a real-time run.
#[derive(Debug)]
pub struct RealtimeReport {
    pub model: RealModel,
    pub makespan_ms: u64,
    pub tasks: usize,
    pub pods: usize,
    pub verify: VerifyReport,
    pub records: Vec<RtTaskRecord>,
}

impl RealtimeReport {
    /// (wait, exec) latency summaries per task type.
    pub fn latency_by_type(&self) -> BTreeMap<String, (Summary, Summary)> {
        let mut m: BTreeMap<String, (Summary, Summary)> = BTreeMap::new();
        for r in &self.records {
            let e = m.entry(r.type_name.clone()).or_default();
            e.0.add((r.started_ms - r.ready_ms) as f64);
            e.1.add((r.finished_ms - r.started_ms) as f64);
        }
        m
    }

    pub fn throughput_tasks_per_s(&self) -> f64 {
        self.tasks as f64 / (self.makespan_ms.max(1) as f64 / 1000.0)
    }
}

const POOLED: [&str; 3] = ["mProject", "mDiffFit", "mBackground"];

struct Shared {
    queues: Mutex<HashMap<String, VecDeque<TaskId>>>,
    cv: Condvar,
    compute: MontageCompute,
    dag: Dag,
    shutdown: AtomicBool,
    pods: AtomicUsize,
    live_workers: Mutex<HashMap<String, usize>>,
    epoch: Instant,
}

impl Shared {
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }
}

type DoneMsg = (TaskId, u64, u64); // task, started_ms, finished_ms

fn worker_loop(
    shared: &Arc<Shared>,
    cfg: &RealtimeConfig,
    pool: &str,
    done: mpsc::Sender<DoneMsg>,
) -> Result<()> {
    // container start + image load: sleep + compile this pool's artifacts
    std::thread::sleep(Duration::from_millis(cfg.pod_start_ms));
    let names = shared.compute.artifacts_for(pool);
    let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
    let rt = Runtime::load_subset(&cfg.artifacts_dir, &name_refs)?;
    let mut idle_since = Instant::now();
    loop {
        if shared.shutdown.load(Ordering::Relaxed) {
            break;
        }
        let task = {
            let mut qs = shared.queues.lock().unwrap();
            loop {
                if let Some(t) = qs.get_mut(pool).and_then(|q| q.pop_front()) {
                    break Some(t);
                }
                if shared.shutdown.load(Ordering::Relaxed)
                    || idle_since.elapsed().as_millis() as u64 > cfg.idle_timeout_ms
                {
                    break None;
                }
                let (g, _to) = shared
                    .cv
                    .wait_timeout(qs, Duration::from_millis(50))
                    .unwrap();
                qs = g;
            }
        };
        let Some(task) = task else {
            break; // scale-to-zero: idle timeout
        };
        let started = shared.now_ms();
        let role = shared.compute.index.role(task);
        shared.compute.execute(&rt, role)?;
        let finished = shared.now_ms();
        done.send((task, started, finished)).ok();
        idle_since = Instant::now();
    }
    let mut live = shared.live_workers.lock().unwrap();
    *live.get_mut(pool).unwrap() -= 1;
    Ok(())
}

/// One-shot job pod: start, load, run a single task, exit.
fn job_pod(
    shared: Arc<Shared>,
    cfg: RealtimeConfig,
    task: TaskId,
    done: mpsc::Sender<DoneMsg>,
) {
    std::thread::spawn(move || -> () {
        std::thread::sleep(Duration::from_millis(cfg.pod_start_ms));
        let tname = shared.dag.type_name(task).to_string();
        let names = shared.compute.artifacts_for(&tname);
        let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
        let rt = match Runtime::load_subset(&cfg.artifacts_dir, &name_refs) {
            Ok(rt) => rt,
            Err(e) => {
                log::error!("job pod for {task:?} failed to load runtime: {e:#}");
                return;
            }
        };
        let started = shared.now_ms();
        let role = shared.compute.index.role(task);
        if let Err(e) = shared.compute.execute(&rt, role) {
            log::error!("task {task:?} failed: {e:#}");
            return;
        }
        let finished = shared.now_ms();
        done.send((task, started, finished)).ok();
    });
}

/// Proportional allocation of the worker quota across pools (the same rule
/// as the simulated autoscaler, one replica per queued task target).
fn desired_workers(
    backlogs: &BTreeMap<String, usize>,
    quota: usize,
) -> BTreeMap<String, usize> {
    let total: usize = backlogs.values().sum();
    let mut out = BTreeMap::new();
    if total == 0 {
        for k in backlogs.keys() {
            out.insert(k.clone(), 0);
        }
        return out;
    }
    if total <= quota {
        for (k, &b) in backlogs {
            out.insert(k.clone(), b);
        }
        return out;
    }
    let mut used = 0usize;
    for (k, &b) in backlogs {
        let share = (quota * b) / total;
        let share = share.min(b).max(usize::from(b > 0));
        used += share;
        out.insert(k.clone(), share);
    }
    // trim if the +1 minimums overflowed the quota
    while used > quota {
        if let Some((_, v)) = out.iter_mut().max_by_key(|(_, v)| **v) {
            if *v > 1 {
                *v -= 1;
                used -= 1;
                continue;
            }
        }
        break;
    }
    out
}

/// Run the Montage workflow for real: full three-layer stack.
pub fn run(cfg: RealtimeConfig) -> Result<RealtimeReport> {
    let wf_cfg = MontageConfig {
        grid_w: cfg.grid,
        grid_h: cfg.grid,
        diagonals: false, // matches the mbgmodel/madd artifact shapes
        seed: cfg.seed,
    };
    let dag = generate(&wf_cfg);
    let n_tasks = dag.len();
    let compute = MontageCompute::prepare(cfg.grid, 128, 32, cfg.seed, cfg.warp);
    let (engine, initial) = Engine::new(generate(&wf_cfg));

    let shared = Arc::new(Shared {
        queues: Mutex::new(
            POOLED
                .iter()
                .map(|p| (p.to_string(), VecDeque::new()))
                .collect(),
        ),
        cv: Condvar::new(),
        compute,
        dag,
        shutdown: AtomicBool::new(false),
        pods: AtomicUsize::new(0),
        live_workers: Mutex::new(POOLED.iter().map(|p| (p.to_string(), 0)).collect()),
        epoch: Instant::now(),
    });

    let (done_tx, done_rx) = mpsc::channel::<DoneMsg>();
    let mut engine = engine;
    let mut ready_ms: HashMap<u32, u64> = HashMap::new();
    let mut records: Vec<Option<RtTaskRecord>> = vec![None; n_tasks];

    // dispatch: pooled types go to queues; everything else is a job pod
    let dispatch = |tasks: Vec<TaskId>,
                    shared: &Arc<Shared>,
                    ready_ms: &mut HashMap<u32, u64>,
                    done_tx: &mpsc::Sender<DoneMsg>| {
        for t in tasks {
            ready_ms.insert(t.0, shared.now_ms());
            let tname = shared.dag.type_name(t).to_string();
            let pooled =
                cfg.model == RealModel::WorkerPools && POOLED.contains(&tname.as_str());
            if pooled {
                let mut qs = shared.queues.lock().unwrap();
                qs.get_mut(&tname).unwrap().push_back(t);
                shared.cv.notify_all();
            } else {
                shared.pods.fetch_add(1, Ordering::Relaxed);
                job_pod(shared.clone(), cfg.clone(), t, done_tx.clone());
            }
        }
    };

    // scaler thread (worker-pools model only)
    let scaler_handle = if cfg.model == RealModel::WorkerPools {
        let shared2 = shared.clone();
        let cfg2 = cfg.clone();
        let done2 = done_tx.clone();
        Some(std::thread::spawn(move || {
            while !shared2.shutdown.load(Ordering::Relaxed) {
                let backlogs: BTreeMap<String, usize> = {
                    let qs = shared2.queues.lock().unwrap();
                    qs.iter().map(|(k, v)| (k.clone(), v.len())).collect()
                };
                let desired = desired_workers(&backlogs, cfg2.max_workers);
                {
                    let mut live = shared2.live_workers.lock().unwrap();
                    for (pool, want) in &desired {
                        let have = live.get_mut(pool).unwrap();
                        while *have < *want {
                            *have += 1;
                            shared2.pods.fetch_add(1, Ordering::Relaxed);
                            let s3 = shared2.clone();
                            let c3 = cfg2.clone();
                            let d3 = done2.clone();
                            let pool3 = pool.clone();
                            std::thread::spawn(move || {
                                if let Err(e) = worker_loop(&s3, &c3, &pool3, d3) {
                                    log::error!("worker for {pool3} died: {e:#}");
                                    let mut live = s3.live_workers.lock().unwrap();
                                    if let Some(n) = live.get_mut(&pool3) {
                                        *n = n.saturating_sub(1);
                                    }
                                }
                            });
                        }
                        // scale-down happens via idle timeout (scale-to-zero)
                    }
                }
                std::thread::sleep(Duration::from_millis(cfg2.poll_ms));
            }
        }))
    } else {
        None
    };

    dispatch(initial, &shared, &mut ready_ms, &done_tx);

    // engine loop: consume completions until the DAG drains
    while !engine.is_done() {
        let (task, started, finished) = done_rx
            .recv_timeout(Duration::from_secs(120))
            .map_err(|_| anyhow!("real-time run stalled (deadlock or worker crash)"))?;
        let tname = shared.dag.type_name(task).to_string();
        records[task.0 as usize] = Some(RtTaskRecord {
            type_name: tname,
            ready_ms: ready_ms[&task.0],
            started_ms: started,
            finished_ms: finished,
        });
        let newly = engine.complete(task);
        dispatch(newly, &shared, &mut ready_ms, &done_tx);
    }
    let makespan_ms = shared.now_ms();
    shared.shutdown.store(true, Ordering::Relaxed);
    shared.cv.notify_all();
    if let Some(h) = scaler_handle {
        h.join().ok();
    }

    let verify = shared.compute.verify()?;
    Ok(RealtimeReport {
        model: cfg.model,
        makespan_ms,
        tasks: n_tasks,
        pods: shared.pods.load(Ordering::Relaxed),
        verify,
        records: records.into_iter().map(Option::unwrap).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn desired_workers_proportional() {
        let mut b = BTreeMap::new();
        b.insert("a".to_string(), 30usize);
        b.insert("b".to_string(), 10usize);
        let d = desired_workers(&b, 8);
        assert_eq!(d["a"] + d["b"], 8);
        assert!(d["a"] >= 5, "{d:?}");
        assert!(d["b"] >= 1, "{d:?}");
    }

    #[test]
    fn desired_workers_uncontended() {
        let mut b = BTreeMap::new();
        b.insert("a".to_string(), 2usize);
        b.insert("b".to_string(), 0usize);
        let d = desired_workers(&b, 8);
        assert_eq!(d["a"], 2);
        assert_eq!(d["b"], 0);
    }

    #[test]
    fn desired_workers_zero() {
        let mut b = BTreeMap::new();
        b.insert("a".to_string(), 0usize);
        let d = desired_workers(&b, 8);
        assert_eq!(d["a"], 0);
    }

    // Full real-time runs live in rust/tests/realtime_e2e.rs (they need
    // `make artifacts`).
}
