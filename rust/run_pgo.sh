#!/bin/sh
# Profile-guided-optimization build for the bench harnesses.
#
# Three phases, standard rustc PGO recipe (see baselines/README.md
# §"PGO builds" for when to use this):
#
#   1. instrument: rebuild the crate with -Cprofile-generate and run a
#      smoke test plus one reduced sweep per bench harness so the
#      profile covers every hot path the benches exercise (event loop,
#      scheduler, fleet driver, chaos/data/isolation planes);
#   2. merge: llvm-profdata merges the raw per-process profiles into
#      one .profdata;
#   3. rebuild: -Cprofile-use recompiles with the merged profile.
#
# The training runs use the same reduced knobs as CI/refresh.sh — the
# sweep points are seeded and deterministic, so the profile is
# reproducible run-to-run modulo thread interleaving (which only moves
# counter magnitudes, not which paths are hot). HF_BENCH_THREADS=2
# during training also exercises the parallel sweep path itself.
#
# Usage, from rust/:
#
#   ./run_pgo.sh                # instrument + train + merge + rebuild
#   PGO_DIR=/tmp/pgo ./run_pgo.sh   # override the profile scratch dir
#
# After it finishes, `target/release/` holds PGO-optimized binaries;
# re-run the benches and compare against baselines/ before committing a
# refresh (perf deltas from PGO are wall-clock only — BENCH JSON
# simulation metrics must not move at all, same seed = same numbers).
set -eu

cd "$(dirname "$0")"

PGO_DIR="${PGO_DIR:-target/pgo-profiles}"
rm -rf "$PGO_DIR"
mkdir -p "$PGO_DIR"
# rustc wants an absolute path for -Cprofile-generate
case "$PGO_DIR" in
    /*) : ;;
    *) PGO_DIR="$(pwd)/$PGO_DIR" ;;
esac

# llvm-profdata ships with the llvm-tools rustup component; fall back
# to the sysroot copy when it is not on PATH.
if command -v llvm-profdata >/dev/null 2>&1; then
    PROFDATA=llvm-profdata
else
    PROFDATA="$(find "$(rustc --print sysroot)/lib/rustlib" \
        -name llvm-profdata -type f 2>/dev/null | head -n 1 || true)"
    if [ -z "$PROFDATA" ]; then
        echo "run_pgo: llvm-profdata not found (install the llvm-tools" >&2
        echo "rustup component: rustup component add llvm-tools)" >&2
        exit 1
    fi
fi

echo "== phase 1: instrumented build + training runs =="
export RUSTFLAGS="-Cprofile-generate=$PGO_DIR"
cargo build --release
# smoke: the tier-1 test suite doubles as broad-coverage training input
cargo test --release -q
# one reduced sweep per harness, same knobs as CI/refresh.sh; two
# threads so the parallel sweep runner itself lands in the profile
export HF_BENCH_THREADS=2
HF_BENCH_GRID=4 HF_BENCH_ITERS=1 cargo bench --bench coordinator_hotpath
HF_FLEET_DURATION=400 HF_FLEET_NODES=4 cargo bench --bench fleet_saturation
HF_CHAOS_GRID=4 HF_CHAOS_RATES=2,4,8 cargo bench --bench chaos_resilience
HF_DATA_GRID=4 HF_DATA_RATES=0.5,2 cargo bench --bench data_locality
HF_ISO_DURATION=1200 HF_ISO_RATE=12 HF_ISO_NODES=6 cargo bench --bench tenant_takeover
unset HF_BENCH_THREADS

echo "== phase 2: merge profiles =="
"$PROFDATA" merge -o "$PGO_DIR/merged.profdata" "$PGO_DIR"

echo "== phase 3: optimized rebuild =="
export RUSTFLAGS="-Cprofile-use=$PGO_DIR/merged.profdata"
cargo build --release

echo "PGO build done — benches in target/release are profile-optimized."
echo "Re-run the harnesses and diff against baselines/ before refreshing:"
echo "  simulation metrics must be bit-identical; only wall-clock moves."
