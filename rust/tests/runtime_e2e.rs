//! Integration tests for the real-compute path: PJRT artifact round-trips
//! beyond the unit level, and full wall-clock runs of the three-layer
//! stack. All tests no-op gracefully when `make artifacts` has not run.

use hyperflow_k8s::compute::MontageCompute;
use hyperflow_k8s::realtime::{run, RealModel, RealtimeConfig};
use hyperflow_k8s::runtime::{Runtime, Tensor};
use hyperflow_k8s::workflow::montage::Role;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn sequential_full_pipeline_matches_ground_truth() {
    // run every task of a 2x2 montage sequentially through PJRT and verify
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let mc = MontageCompute::prepare(2, 128, 32, 99, false);
    let n = 4;
    let e = mc.index.pairs().len();
    for i in 0..n {
        mc.execute(&rt, Role::Project(i)).unwrap();
    }
    for k in 0..e {
        let pair = mc.index.pairs()[k];
        mc.execute(&rt, Role::DiffFit(k, pair)).unwrap();
    }
    mc.execute(&rt, Role::ConcatFit).unwrap();
    mc.execute(&rt, Role::BgModel).unwrap();
    for i in 0..n {
        mc.execute(&rt, Role::Background(i)).unwrap();
    }
    mc.execute(&rt, Role::Imgtbl).unwrap();
    mc.execute(&rt, Role::Add).unwrap();
    mc.execute(&rt, Role::Shrink).unwrap();
    mc.execute(&rt, Role::Jpeg).unwrap();

    let v = mc.verify().unwrap();
    assert!(
        v.max_mosaic_residual < 0.01,
        "mosaic residual {} too large",
        v.max_mosaic_residual
    );
    assert!(
        v.max_offset_error < 0.01,
        "offset error {}",
        v.max_offset_error
    );
    // preview produced by the mJPEG stage
    assert!(mc.store.contains("preview"));
}

#[test]
fn warped_pipeline_still_converges() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let mc = MontageCompute::prepare(2, 128, 32, 5, true);
    let n = 4;
    for i in 0..n {
        mc.execute(&rt, Role::Project(i)).unwrap();
    }
    for k in 0..mc.index.pairs().len() {
        let pair = mc.index.pairs()[k];
        mc.execute(&rt, Role::DiffFit(k, pair)).unwrap();
    }
    mc.execute(&rt, Role::ConcatFit).unwrap();
    mc.execute(&rt, Role::BgModel).unwrap();
    for i in 0..n {
        mc.execute(&rt, Role::Background(i)).unwrap();
    }
    mc.execute(&rt, Role::Imgtbl).unwrap();
    mc.execute(&rt, Role::Add).unwrap();
    let v = mc.verify().unwrap();
    assert!(v.max_mosaic_residual < 0.15, "residual {}", v.max_mosaic_residual);
    assert!(v.max_offset_error < 0.1, "offset err {}", v.max_offset_error);
}

#[test]
fn mbgmodel_artifact_solves_known_system() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_subset(&dir, &["mbgmodel_g2"]).unwrap();
    // 2x2 grid: edges (0,1),(0,2),(1,3),(2,3); true offsets [1,-1,2,-2]
    let offs = [1.0f32, -1.0, 2.0, -2.0];
    let pairs = [(0usize, 1usize), (0, 2), (1, 3), (2, 3)];
    let src: Vec<i32> = pairs.iter().map(|&(a, _)| a as i32).collect();
    let dst: Vec<i32> = pairs.iter().map(|&(_, b)| b as i32).collect();
    let d: Vec<f32> = pairs.iter().map(|&(a, b)| offs[a] - offs[b]).collect();
    let out = rt
        .execute(
            "mbgmodel_g2",
            &[
                Tensor::from_i32(&src, &[4]),
                Tensor::from_i32(&dst, &[4]),
                Tensor::new(d, &[4]),
                Tensor::new(vec![1.0; 4], &[4]),
            ],
        )
        .unwrap();
    let got = &out[0].data;
    for (g, w) in got.iter().zip(offs.iter()) {
        assert!((g - w).abs() < 5e-3, "got {got:?} want {offs:?}");
    }
}

#[test]
fn madd_artifact_coadds_with_weights() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_subset(&dir, &["madd_g2"]).unwrap();
    let t = 128;
    let n = 4;
    let step = 96;
    let c = step + t; // 224 canvas for g=2
    let imgs = vec![3.0f32; n * t * t];
    let ws = vec![1.0f32; n * t * t];
    let oy: Vec<i32> = (0..n).map(|i| ((i / 2) * step) as i32).collect();
    let ox: Vec<i32> = (0..n).map(|i| ((i % 2) * step) as i32).collect();
    let out = rt
        .execute(
            "madd_g2",
            &[
                Tensor::new(imgs, &[n, t, t]),
                Tensor::new(ws, &[n, t, t]),
                Tensor::from_i32(&oy, &[n]),
                Tensor::from_i32(&ox, &[n]),
            ],
        )
        .unwrap();
    let mosaic = &out[2];
    assert_eq!(mosaic.shape, vec![c, c]);
    // constant tiles with weight 1 -> mosaic is 3 everywhere covered
    for &v in &mosaic.data {
        assert!((v - 3.0).abs() < 1e-5, "mosaic value {v}");
    }
    // weight map: overlap strips accumulate to 2 and 4
    let wmap = &out[1];
    assert_eq!(wmap.data[0], 1.0); // corner: single tile
    assert_eq!(wmap.data[(c / 2) * c + c / 2], 4.0); // center: all four tiles
}

#[test]
fn realtime_pools_run_verifies() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = RealtimeConfig {
        grid: 2,
        artifacts_dir: dir,
        pod_start_ms: 30,
        poll_ms: 25,
        idle_timeout_ms: 250,
        max_workers: 2,
        model: RealModel::WorkerPools,
        seed: 3,
        warp: false,
    };
    let report = run(cfg).unwrap();
    assert_eq!(report.tasks, 18); // 2x2: 4 mProject + 4 mDiffFit + 4 mBackground + 6 serial
    assert!(report.verify.ok(0.02), "verify failed: {:?}", report.verify);
    assert!(report.makespan_ms > 0);
    assert!(report.pods > 0);
    // every record consistent
    for r in &report.records {
        assert!(r.finished_ms >= r.started_ms);
        assert!(r.started_ms >= r.ready_ms);
    }
}

#[test]
fn realtime_jobs_run_verifies_and_churns_pods() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = RealtimeConfig {
        grid: 2,
        artifacts_dir: dir,
        pod_start_ms: 10,
        poll_ms: 25,
        idle_timeout_ms: 250,
        max_workers: 2,
        model: RealModel::Jobs,
        seed: 3,
        warp: false,
    };
    let report = run(cfg).unwrap();
    assert!(report.verify.ok(0.02));
    // job model: one pod per task (the paper's churn pathology, for real)
    assert_eq!(report.pods, report.tasks);
}
