//! Parallel-sweep contract tests: the bench fan-out (`util::sweep`) must
//! produce results byte-identical to the serial loop — the whole point of
//! the runner is that `HF_BENCH_THREADS=N` changes wall-clock only, never
//! a single byte of any `BENCH_*.json` or printed table. These tests pin
//! that contract through the library API (full simulations snapshotted to
//! JSON, compared as strings), plus the arena-reuse accounting the
//! overhaul added to the event queue.

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::obs::snapshot;
use hyperflow_k8s::sim::{EventQueue, SimTime};
use hyperflow_k8s::util::sweep;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn montage(g: usize, seed: u64) -> hyperflow_k8s::workflow::dag::Dag {
    generate(&MontageConfig {
        grid_w: g,
        grid_h: g,
        diagonals: true,
        seed,
    })
}

fn all_models() -> Vec<ExecModel> {
    vec![
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
        ExecModel::GenericPool,
    ]
}

/// The acceptance bar for the tentpole: fanning a model sweep across 4
/// workers must reproduce the serial snapshots byte-for-byte. Snapshots
/// cover the full result surface (makespan, counters, trace-derived
/// rows), so a single reordered event anywhere would flip the string.
#[test]
fn parallel_sweep_snapshots_are_byte_identical_to_serial() {
    let run_points = |threads: usize| -> Vec<String> {
        sweep::run_on(threads, all_models(), |_, model| {
            let cfg = driver::SimConfig::with_nodes(5).obs(true);
            let res = driver::run(montage(6, 42), model, cfg.clone());
            snapshot::capture(&res, &cfg).to_string()
        })
    };
    let serial = run_points(1);
    assert_eq!(serial.len(), 4);
    for threads in [2, 4, 8] {
        assert_eq!(
            run_points(threads),
            serial,
            "{threads}-thread sweep diverged from the serial reference"
        );
    }
}

/// Same contract for a heterogeneous grid (the flattened model x point
/// shape the real benches use): mixed workloads with very different
/// runtimes must still collect in point order.
#[test]
fn heterogeneous_grid_collects_in_point_order() {
    // (grid, seed) points with deliberately skewed cost: big grids early
    // so slow points finish *after* fast ones claimed later indices
    let pts: Vec<(usize, u64)> = vec![(8, 42), (4, 42), (8, 7), (4, 7), (6, 3), (4, 3)];
    let run_points = |threads: usize| -> Vec<(u64, u64)> {
        sweep::run_on(threads, pts.clone(), |_, (g, seed)| {
            let res = driver::run(
                montage(g, seed),
                ExecModel::paper_hybrid_pools(),
                driver::SimConfig::with_nodes(4),
            );
            (res.makespan.as_millis(), res.sim_events)
        })
    };
    let serial = run_points(1);
    assert_eq!(run_points(4), serial, "grid sweep reordered or diverged");
    // skewed inputs must actually produce distinct results for the
    // order check to mean anything
    assert_ne!(serial[0], serial[1]);
}

/// Arena accounting sanity on a real run: the steady-state event loop
/// must recycle — slab growth stops at peak concurrency while schedules
/// keep coming, so a non-trivial simulation reuses far more slots than
/// it allocates.
#[test]
fn real_runs_recycle_event_slots() {
    let res = driver::run(
        montage(8, 42),
        ExecModel::paper_hybrid_pools(),
        driver::SimConfig::with_nodes(5),
    );
    let a = res.event_arena;
    assert!(a.allocs > 0, "a run must schedule events");
    assert!(a.reuses > 0, "steady state must hit the free list");
    assert!(
        a.reuse_ratio() > 0.5,
        "peak concurrency is far below total events scheduled \
         (allocs {} reuses {})",
        a.allocs,
        a.reuses
    );
    // total schedules can never be below the event count that popped
    assert!(a.allocs + a.reuses >= res.sim_events);
}

/// Free-list recycling must not disturb the (time, schedule-order) pop
/// contract: ties scheduled into recycled slots still pop FIFO, and
/// recycling drained slots must not grow the slab.
#[test]
fn free_list_recycling_preserves_fifo_ties() {
    let mut q: EventQueue<u32> = EventQueue::new();
    for i in 0..8 {
        q.schedule_at(SimTime(10), i);
    }
    for i in 0..8 {
        assert_eq!(q.pop(), Some((SimTime(10), i)), "cold FIFO order");
    }
    let s = q.arena_stats();
    assert_eq!((s.allocs, s.reuses), (8, 0), "cold pass grows the slab");

    // the LIFO free list hands slots back in reverse drain order — the
    // FIFO tie-break must come from the bucket links, not slot indices
    for i in 100..108 {
        q.schedule_at(SimTime(20), i);
    }
    for i in 100..108 {
        assert_eq!(q.pop(), Some((SimTime(20), i)), "recycled FIFO order");
    }
    let s = q.arena_stats();
    assert_eq!(s.allocs, 8, "warm pass must not grow the slab");
    assert_eq!(s.reuses, 8, "warm pass must recycle every slot");
    assert_eq!(s.reuse_ratio(), 0.5);
}

/// Interleaved schedule/pop churn (the shape the simulator actually
/// drives) across wheel and overflow timestamps: order must match a
/// stable sort by (time, schedule sequence) throughout.
#[test]
fn interleaved_churn_matches_stable_order() {
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut expect: Vec<(u64, u64)> = Vec::new();
    let mut seq = 0u64;
    // deterministic LCG so the pattern is reproducible
    let mut rng = 0x2545F491u64;
    let mut next = || {
        rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        rng >> 33
    };
    let mut popped: Vec<(u64, u64)> = Vec::new();
    for round in 0..200 {
        // burst of schedules, some colliding on the same timestamp and
        // some past the wheel horizon (exercises the overflow sweep)
        for _ in 0..(1 + next() % 4) {
            let t = q.now().0 + 1 + next() % 200_000;
            q.schedule_at(SimTime(t), seq);
            expect.push((t, seq));
            seq += 1;
        }
        if round % 3 != 0 {
            if let Some((t, e)) = q.pop() {
                popped.push((t.0, e));
            }
        }
    }
    while let Some((t, e)) = q.pop() {
        popped.push((t.0, e));
    }
    // reference: stable sort by time keeps schedule order within ties —
    // but pops interleave with schedules, so compare only the global
    // multiset and the per-timestamp FIFO suborder
    assert_eq!(popped.len(), expect.len(), "lost or duplicated events");
    let mut by_time_popped = popped.clone();
    by_time_popped.sort_by_key(|&(t, _)| t);
    for w in by_time_popped.windows(2) {
        if w[0].0 == w[1].0 {
            assert!(
                w[0].1 < w[1].1,
                "same-time events popped out of schedule order at t={}",
                w[0].0
            );
        }
    }
    let s = q.arena_stats();
    assert!(s.reuses > 0, "churn must recycle slots");
    assert!(
        (s.allocs as usize) < expect.len(),
        "slab grew once per event — free list is dead"
    );
}
