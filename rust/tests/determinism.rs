//! Determinism regression tests: the calendar event queue replaced the
//! binary heap (see rust/src/sim/event.rs), and the whole figure pipeline
//! depends on the (time, schedule-order) pop contract surviving that swap.
//! Running the same configuration twice must produce *identical* results —
//! makespan, bind and back-off counts, pod/API counters, and event totals
//! — for every execution model.

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::fleet::{self, ArrivalProcess, FleetConfig};
use hyperflow_k8s::models::multicloud::{self, McConfig, McMode};
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn montage(g: usize, seed: u64) -> hyperflow_k8s::workflow::dag::Dag {
    generate(&MontageConfig {
        grid_w: g,
        grid_h: g,
        diagonals: true,
        seed,
    })
}

fn all_models() -> Vec<ExecModel> {
    vec![
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
        ExecModel::GenericPool,
    ]
}

/// Same seed + model twice => identical fingerprint. `sched_binds` and
/// `sched_backoffs` are the most ordering-sensitive counters: a single
/// same-timestamp FIFO violation in the event queue reorders a bind and
/// shifts both.
#[test]
fn rerun_is_bit_identical_for_every_model() {
    for model in all_models() {
        let a = driver::run(montage(8, 42), model.clone(), driver::SimConfig::with_nodes(5));
        let b = driver::run(montage(8, 42), model.clone(), driver::SimConfig::with_nodes(5));
        let name = model.name();
        assert_eq!(a.makespan, b.makespan, "{name}: makespan");
        assert_eq!(a.sched_binds, b.sched_binds, "{name}: binds_total");
        assert_eq!(a.sched_backoffs, b.sched_backoffs, "{name}: backoffs_total");
        assert_eq!(a.pods_created, b.pods_created, "{name}: pods");
        assert_eq!(a.api_requests, b.api_requests, "{name}: api requests");
        assert_eq!(a.sim_events, b.sim_events, "{name}: event count");
        assert_eq!(
            a.avg_running_tasks, b.avg_running_tasks,
            "{name}: running-task series diverged"
        );
    }
}

/// Determinism must also hold with the failure-injection RNG and node
/// up/down events active (both feed extra events through the queue).
#[test]
fn rerun_is_bit_identical_under_failure_injection() {
    for model in all_models() {
        let mk_cfg = || {
            let mut cfg = driver::SimConfig::with_nodes(4);
            cfg.pod_failure_prob = 0.05;
            cfg.seed = 7;
            cfg.node_events = vec![(40_000, 1, false), (180_000, 1, true)];
            cfg
        };
        let a = driver::run(montage(6, 3), model.clone(), mk_cfg());
        let b = driver::run(montage(6, 3), model.clone(), mk_cfg());
        let name = model.name();
        assert_eq!(a.makespan, b.makespan, "{name}: makespan under failures");
        assert_eq!(a.sched_binds, b.sched_binds, "{name}: binds under failures");
        assert_eq!(
            a.sched_backoffs, b.sched_backoffs,
            "{name}: backoffs under failures"
        );
        assert_eq!(a.sim_events, b.sim_events, "{name}: events under failures");
    }
}

/// Different seeds must (generically) diverge — guards against the
/// fingerprint accidentally ignoring the inputs.
#[test]
fn different_seed_changes_the_run() {
    let a = driver::run(montage(8, 42), ExecModel::JobBased, driver::SimConfig::with_nodes(5));
    let b = driver::run(montage(8, 43), ExecModel::JobBased, driver::SimConfig::with_nodes(5));
    assert_ne!(a.makespan, b.makespan, "distinct workloads, same makespan?");
}

/// The multicloud model must be deterministic too: a fixed-seed 2-cluster
/// pools run reproduces its makespan, transfer count and per-cloud task
/// placement bit-identically. (Cross-cloud transfer accounting depends on
/// event order even more tightly than the single-cluster counters.)
#[test]
fn multicloud_pools_rerun_is_bit_identical() {
    let mk = || {
        multicloud::run(
            montage(6, 11),
            McConfig {
                clusters: vec![3, 2],
                mode: McMode::Pools,
                ..Default::default()
            },
        )
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.makespan, b.makespan, "multicloud makespan");
    assert_eq!(a.transfers, b.transfers, "cross-cloud transfer count");
    assert_eq!(a.pods_created, b.pods_created, "multicloud pods");
    assert_eq!(a.tasks_per_cloud, b.tasks_per_cloud, "task placement");
    assert!(a.transfers > 0, "2-cluster split must pay transfers");
}

/// Chaos rerun contract (the acceptance bar for `--chaos`): identical
/// seed + chaos spec must reproduce the run bit-identically — makespan,
/// wasted-work and retry counts included — for the pools model (the
/// single-cluster counterpart of `McMode::Pools`) and the job model.
/// Fault timelines are lazily-sampled Poisson processes, so this guards
/// the whole draw-in-event-order discipline.
#[test]
fn chaos_rerun_reproduces_makespan_waste_and_retries() {
    for model in [ExecModel::paper_hybrid_pools(), ExecModel::JobBased] {
        let mk = || {
            let mut cfg = driver::SimConfig::with_nodes(4);
            cfg.seed = 7;
            cfg.chaos = hyperflow_k8s::chaos::ChaosConfig::parse_spec("spot:0.2").unwrap();
            driver::run(montage(6, 3), model.clone(), cfg)
        };
        let (a, b) = (mk(), mk());
        let name = model.name();
        assert_eq!(a.makespan, b.makespan, "{name}: makespan under spot churn");
        assert_eq!(a.chaos.wasted_ms, b.chaos.wasted_ms, "{name}: wasted work");
        assert_eq!(a.chaos.retries, b.chaos.retries, "{name}: retry count");
        assert_eq!(a.chaos.spot_reclaims, b.chaos.spot_reclaims, "{name}: reclaims");
        assert_eq!(a.sim_events, b.sim_events, "{name}: event count");
        assert_eq!(a.sched_binds, b.sched_binds, "{name}: binds");
    }
    // heavier spec exercising every injector + recovery mechanism at once
    let mk = || {
        let mut cfg = driver::SimConfig::with_nodes(4);
        cfg.seed = 11;
        cfg.chaos = hyperflow_k8s::chaos::ChaosConfig::parse_spec(
            "spot:2,crash:1,pod:0.1,straggler:0.5",
        )
        .unwrap();
        driver::run(montage(6, 3), ExecModel::paper_hybrid_pools(), cfg)
    };
    let (a, b) = (mk(), mk());
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.chaos.wasted_ms, b.chaos.wasted_ms);
    assert_eq!(a.chaos.retries, b.chaos.retries);
    assert_eq!(a.chaos.speculations, b.chaos.speculations);
    assert_eq!(a.chaos.recovery_p99_s, b.chaos.recovery_p99_s);
    assert_eq!(a.sim_events, b.sim_events);
}

/// Chaos fleet runs must reproduce too — the fault processes interleave
/// with open-loop arrivals through one calendar queue.
#[test]
fn chaos_fleet_rerun_is_bit_identical() {
    let mk = || {
        let cfg = FleetConfig {
            arrival: ArrivalProcess::Poisson { per_hour: 60.0 },
            duration_s: 400.0,
            tenants: fleet::default_tenants(2, &[3, 4]),
            seed: 42,
            max_in_flight: None,
        };
        let mut sim = driver::SimConfig::with_nodes(4);
        sim.seed = 42;
        sim.chaos = hyperflow_k8s::chaos::ChaosConfig::parse_spec("spot:1,pod:0.05").unwrap();
        fleet::run(ExecModel::paper_hybrid_pools(), sim, &cfg)
    };
    let (a, b) = (mk(), mk());
    assert_eq!(a.sim.makespan, b.sim.makespan);
    assert_eq!(a.sim.sim_events, b.sim.sim_events);
    assert_eq!(a.sim.chaos.wasted_ms, b.sim.chaos.wasted_ms);
    assert_eq!(a.sim.chaos.retries_by_tenant, b.sim.chaos.retries_by_tenant);
    assert_eq!(
        fleet::report::render_table(&a),
        fleet::report::render_table(&b)
    );
}

/// Data-plane rerun contract (the acceptance bar for `--data`): identical
/// seed + data spec must reproduce the run bit-identically — makespan,
/// bytes moved, cache hits and stage-in percentiles included — for the
/// pools and job models on the NFS backend. Transfer rates are max-min
/// fair shares recomputed on every flow start/finish, so this guards the
/// whole piecewise-constant-rate timeline.
#[test]
fn data_rerun_reproduces_bytes_and_stage_in_tail() {
    for model in [ExecModel::paper_hybrid_pools(), ExecModel::JobBased] {
        let mk = || {
            let mut cfg = driver::SimConfig::with_nodes(4);
            cfg.seed = 7;
            cfg.data =
                Some(hyperflow_k8s::data::DataConfig::parse_spec("nfs:0.5,cache:4").unwrap());
            driver::run(montage(6, 3), model.clone(), cfg)
        };
        let (a, b) = (mk(), mk());
        let name = model.name();
        assert_eq!(a.makespan, b.makespan, "{name}: makespan under I/O");
        assert_eq!(a.data.bytes_in, b.data.bytes_in, "{name}: bytes in");
        assert_eq!(a.data.bytes_out, b.data.bytes_out, "{name}: bytes out");
        assert_eq!(a.data.bytes_hit, b.data.bytes_hit, "{name}: cache hits");
        assert_eq!(a.data.transfers, b.data.transfers, "{name}: transfers");
        assert_eq!(
            a.data.stage_in_p95_s, b.data.stage_in_p95_s,
            "{name}: stage-in p95"
        );
        assert_eq!(a.sim_events, b.sim_events, "{name}: event count");
        assert_eq!(a.sched_binds, b.sched_binds, "{name}: binds");
        assert!(a.data.bytes_in > 0, "{name}: the data plane must be live");
    }
}

/// A fleet run with the data plane *and* locality-aware placement active
/// must reproduce too — locality scoring feeds the scheduler from mutable
/// cache state, so any nondeterminism there would shift placements.
#[test]
fn data_fleet_with_locality_rerun_is_bit_identical() {
    let mk = || {
        let cfg = FleetConfig {
            arrival: ArrivalProcess::Poisson { per_hour: 60.0 },
            duration_s: 400.0,
            tenants: fleet::default_tenants(2, &[3, 4]),
            seed: 42,
            max_in_flight: None,
        };
        let mut sim = driver::SimConfig::with_nodes(4);
        sim.seed = 42;
        sim.data = Some(
            hyperflow_k8s::data::DataConfig::parse_spec("nfs:1,cache:4,locality:on").unwrap(),
        );
        fleet::run(ExecModel::paper_hybrid_pools(), sim, &cfg)
    };
    let (a, b) = (mk(), mk());
    assert_eq!(a.sim.makespan, b.sim.makespan);
    assert_eq!(a.sim.sim_events, b.sim.sim_events);
    assert_eq!(a.sim.data.bytes_in, b.sim.data.bytes_in);
    assert_eq!(a.sim.data.bytes_by_tenant, b.sim.data.bytes_by_tenant);
    assert_eq!(
        fleet::report::render_table(&a),
        fleet::report::render_table(&b)
    );
    assert!(a.sim.data.enabled && a.sim.data.bytes_in > 0);
}

/// Regression: with the data plane *off* (the default), runs must carry an
/// all-zero data report and reproduce the baseline makespans exactly —
/// the `--data` flag gates every data-plane code path, so disabled runs
/// stay bit-identical to pre-data builds. (The cross-PR half of that
/// contract is enforced by the untouched rerun tests above, which run the
/// same `data: None` path the pre-data driver ran.)
#[test]
fn data_off_reproduces_baseline_makespans() {
    for model in all_models() {
        let mk = || {
            let cfg = driver::SimConfig::with_nodes(5);
            assert!(cfg.data.is_none(), "data plane must default to off");
            driver::run(montage(8, 42), model.clone(), cfg)
        };
        let (a, b) = (mk(), mk());
        let name = model.name();
        assert!(!a.data.enabled, "{name}: disabled runs report no data");
        assert_eq!(a.data.bytes_in + a.data.bytes_out, 0, "{name}");
        assert_eq!(a.data.stage_ins, 0, "{name}: no stage events scheduled");
        assert_eq!(a.makespan, b.makespan, "{name}: baseline makespan");
        assert_eq!(a.sim_events, b.sim_events, "{name}: baseline event count");
    }
}

/// Isolation + takeover rerun contract (the acceptance bar for
/// `--isolation` and `takeover:T@S`): a fleet run with per-tenant quotas,
/// node-pool partitioning, lane-constrained worker fetches and a
/// mid-window tenant compromise must reproduce the whole SLO table —
/// blast radius, quota throttles, violations and innocent exposure
/// included — bit-identically from the seed.
#[test]
fn isolation_takeover_fleet_rerun_is_bit_identical() {
    for spec in ["shared,quota:16000x65536", "dedicated,quota:16000x65536", "sandboxed"] {
        let mk = || {
            let cfg = FleetConfig {
                arrival: ArrivalProcess::Poisson { per_hour: 60.0 },
                duration_s: 400.0,
                tenants: fleet::default_tenants(2, &[3, 4]),
                seed: 42,
                max_in_flight: None,
            };
            let mut sim = driver::SimConfig::with_nodes(4);
            sim.seed = 42;
            sim.isolation =
                Some(hyperflow_k8s::k8s::isolation::IsolationConfig::parse_spec(spec).unwrap());
            sim.chaos = hyperflow_k8s::chaos::ChaosConfig::parse_spec("takeover:0@200").unwrap();
            fleet::run(ExecModel::paper_hybrid_pools(), sim, &cfg)
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.sim.makespan, b.sim.makespan, "{spec}: makespan");
        assert_eq!(a.sim.sim_events, b.sim.sim_events, "{spec}: event count");
        assert!(a.sim.isolation.enabled, "{spec}: isolation must be live");
        assert_eq!(a.sim.isolation.takeovers, 1, "{spec}: one takeover fired");
        assert_eq!(
            a.sim.isolation.blast_nodes, b.sim.isolation.blast_nodes,
            "{spec}: blast nodes"
        );
        assert_eq!(
            a.sim.isolation.quota_throttles_by_tenant,
            b.sim.isolation.quota_throttles_by_tenant,
            "{spec}: throttles"
        );
        assert_eq!(
            a.sim.isolation.takeover_exposed_ms_by_tenant,
            b.sim.isolation.takeover_exposed_ms_by_tenant,
            "{spec}: innocent exposure"
        );
        assert_eq!(
            fleet::report::render_table(&a),
            fleet::report::render_table(&b),
            "{spec}: SLO table diverged across reruns"
        );
        // the sandbox contains the escape: no foreign nodes are reachable
        if spec.starts_with("sandboxed") {
            assert_eq!(a.sim.isolation.blast_nodes, 0, "sandbox must contain the blast");
            assert_eq!(a.sim.isolation.blast_innocent_pods, 0, "no innocent pods reached");
        }
    }
}

/// Regression: with `--isolation` unset (the default), runs must carry an
/// all-zero isolation report and reproduce the baseline makespans exactly
/// — the flag gates every isolation code path (admission, placement
/// filtering, lane-constrained fetch, sandbox start overhead), so
/// disabled runs stay bit-identical to pre-isolation builds.
#[test]
fn isolation_off_reproduces_baseline_makespans() {
    for model in all_models() {
        let mk = || {
            let cfg = driver::SimConfig::with_nodes(5);
            assert!(cfg.isolation.is_none(), "isolation must default to off");
            driver::run(montage(8, 42), model.clone(), cfg)
        };
        let (a, b) = (mk(), mk());
        let name = model.name();
        assert!(!a.isolation.enabled, "{name}: disabled runs report no isolation");
        assert_eq!(a.isolation.takeovers, 0, "{name}: no takeovers scheduled");
        assert_eq!(
            a.isolation.quota_throttles() + a.isolation.violations(),
            0,
            "{name}: no isolation accounting off the flag"
        );
        assert_eq!(a.makespan, b.makespan, "{name}: baseline makespan");
        assert_eq!(a.sim_events, b.sim_events, "{name}: baseline event count");
    }
}

/// Fleet runs (open-loop arrivals, tenancy, fair-share lanes, admission
/// control) must reproduce the per-tenant slowdown table from the seed —
/// the acceptance contract of `hyperflow serve`.
#[test]
fn fleet_rerun_reproduces_the_slowdown_table() {
    let mk = || {
        let cfg = FleetConfig {
            arrival: ArrivalProcess::Poisson { per_hour: 90.0 },
            duration_s: 400.0,
            tenants: fleet::default_tenants(2, &[3, 4]),
            seed: 42,
            max_in_flight: Some(3),
        };
        fleet::run(
            ExecModel::paper_hybrid_pools(),
            driver::SimConfig::with_nodes(4),
            &cfg,
        )
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.sim.makespan, b.sim.makespan, "fleet makespan");
    assert_eq!(a.sim.sim_events, b.sim.sim_events, "fleet event count");
    assert_eq!(
        fleet::report::render_table(&a),
        fleet::report::render_table(&b),
        "per-tenant slowdown table diverged across reruns"
    );
}
