//! Flight-recorder integration contract.
//!
//! Two properties pin the "observe, never perturb" design:
//!
//! 1. the simulation fingerprint (makespan, event count, pods, binds,
//!    back-offs) is bit-identical with and without the recorder attached
//!    — recording draws no RNG and schedules no calendar events;
//! 2. with the recorder on, critical-path attribution decomposes the
//!    makespan *exactly* (integer milliseconds) into queueing /
//!    scheduling / pod-start / stage-in / compute / stage-out / recovery
//!    for every execution model, plain and with chaos or the data plane
//!    attached.

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::exec::{run, run_fleet, ExecModel, SimConfig};
use hyperflow_k8s::fleet::{FleetPlan, InstanceSpec};
use hyperflow_k8s::workflow::dag::Dag;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn fixed_dag() -> Dag {
    generate(&MontageConfig {
        grid_w: 4,
        grid_h: 4,
        diagonals: true,
        seed: 11,
    })
}

fn all_models() -> Vec<ExecModel> {
    vec![
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
        ExecModel::GenericPool,
    ]
}

/// The three run configurations exercised per model: healthy cluster,
/// every chaos injector, and a constrained shared-NFS data plane.
fn configs(obs: bool) -> Vec<(&'static str, SimConfig)> {
    let plain = SimConfig::with_nodes(4).obs(obs);
    let mut chaos = SimConfig::with_nodes(4);
    chaos.seed = 7;
    chaos.chaos =
        hyperflow_k8s::chaos::ChaosConfig::parse_spec("spot:2,crash:1,pod:0.1,straggler:0.5")
            .unwrap();
    let chaos = chaos.obs(obs);
    let mut data = SimConfig::with_nodes(4);
    data.data = Some(hyperflow_k8s::data::DataConfig::parse_spec("nfs:0.5,cache:4").unwrap());
    let data = data.obs(obs);
    vec![("plain", plain), ("chaos", chaos), ("data", data)]
}

/// Ordering-sensitive simulation fingerprint (same counters as the golden
/// trace): any recorder-induced perturbation shifts at least one field.
fn fingerprint(obs: bool) -> String {
    let mut out = String::new();
    for model in all_models() {
        for (tag, cfg) in configs(obs) {
            let res = run(fixed_dag(), model.clone(), cfg);
            out.push_str(&format!(
                "{tag}/{}: makespan_ms={} events={} pods={} binds={} backoffs={} api={}\n",
                model.name(),
                res.makespan.as_millis(),
                res.sim_events,
                res.pods_created,
                res.sched_binds,
                res.sched_backoffs,
                res.api_requests,
            ));
        }
    }
    out
}

#[test]
fn recording_does_not_perturb_the_simulation() {
    assert_eq!(
        fingerprint(false),
        fingerprint(true),
        "attaching the flight recorder changed the simulated trace"
    );
}

#[test]
fn obs_runs_are_bit_identical_on_rerun() {
    assert_eq!(
        fingerprint(true),
        fingerprint(true),
        "recorder-on rerun diverged"
    );
}

#[test]
fn attribution_sums_to_makespan_for_every_model() {
    for model in all_models() {
        for (tag, cfg) in configs(true) {
            let res = run(fixed_dag(), model.clone(), cfg);
            let o = res
                .obs
                .as_ref()
                .unwrap_or_else(|| panic!("{tag}/{}: recorder missing", model.name()));
            let a = o
                .attribution
                .as_ref()
                .unwrap_or_else(|| panic!("{tag}/{}: no attribution", model.name()));
            assert_eq!(
                a.total_ms(),
                res.makespan.as_millis(),
                "{tag}/{}: phase decomposition must telescope to the makespan \
                 (path of {} tasks: {:?})",
                model.name(),
                a.path_tasks,
                o.critical_path,
            );
            assert!(
                !o.critical_path.is_empty(),
                "{tag}/{}: empty critical path",
                model.name()
            );
            assert!(
                !o.events.is_empty(),
                "{tag}/{}: no control-plane events recorded",
                model.name()
            );
            assert!(
                !o.pods.is_empty(),
                "{tag}/{}: no pod lanes harvested",
                model.name()
            );
        }
    }
}

#[test]
fn fleet_runs_attribute_each_instance_from_its_admission() {
    let (a, b) = (fixed_dag(), fixed_dag());
    let (n_a, n_b) = (a.len() as u32, b.len() as u32);
    let union = Dag::disjoint_union(&[a, b]);
    let plan = FleetPlan {
        instances: vec![
            InstanceSpec {
                tenant: 0,
                arrival_ms: 0,
                first_task: 0,
                n_tasks: n_a,
            },
            InstanceSpec {
                tenant: 1,
                arrival_ms: 20_000,
                first_task: n_a,
                n_tasks: n_b,
            },
        ],
        tenant_weights: vec![2, 1],
        max_in_flight: None,
    };
    let (res, outcomes) = run_fleet(
        union,
        ExecModel::paper_hybrid_pools(),
        SimConfig::with_nodes(4).obs(true),
        &plan,
    );
    let o = res.obs.as_ref().expect("recorder attached");
    assert_eq!(o.instance_attr.len(), outcomes.len());
    for (i, (attr, out)) in o.instance_attr.iter().zip(&outcomes).enumerate() {
        let attr = attr.as_ref().unwrap_or_else(|| panic!("instance {i} unattributed"));
        assert_eq!(
            attr.total_ms(),
            (out.finished - out.admitted).as_millis(),
            "instance {i}: attribution must telescope from admission to finish"
        );
    }
}
