//! Monitoring-stack integration contract.
//!
//! Three properties pin the "scrape, never perturb" design:
//!
//! 1. the simulation fingerprint (makespan, pods, binds, back-offs, API
//!    requests) is bit-identical with and without the monitor attached —
//!    scrapes draw no RNG and read kernel state without mutating it
//!    (`sim_events` is deliberately excluded: the scrape calendar adds
//!    `MonitorTick` events, which is the one permitted difference);
//! 2. a monitor-on rerun with the same seed reproduces the entire alert
//!    report byte-for-byte — the "alerts file is part of the golden
//!    trace" guarantee;
//! 3. a resource-starved chaos fleet drives the builtin rules through
//!    real alert lifecycles: `BacklogSaturation` (queue threshold with a
//!    `for:` hold) and `TaskDisruptionBudget` (multi-window SLO
//!    burn-rate) must both fire.

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::exec::{run, run_fleet, ExecModel, SimConfig};
use hyperflow_k8s::fleet::{FleetPlan, InstanceSpec};
use hyperflow_k8s::report::SimResult;
use hyperflow_k8s::obs::monitor::MonitorConfig;
use hyperflow_k8s::workflow::dag::Dag;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn fixed_dag() -> Dag {
    generate(&MontageConfig {
        grid_w: 4,
        grid_h: 4,
        diagonals: true,
        seed: 11,
    })
}

fn all_models() -> Vec<ExecModel> {
    vec![
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
        ExecModel::GenericPool,
    ]
}

/// The three run configurations exercised per model: healthy cluster,
/// every chaos injector, and a constrained shared-NFS data plane (the
/// data config also selects the cache-aware builtin rules).
fn configs(monitor: bool) -> Vec<(&'static str, SimConfig)> {
    let mut out = Vec::new();
    let plain = SimConfig::with_nodes(4);
    let mut chaos = SimConfig::with_nodes(4);
    chaos.seed = 7;
    chaos.chaos =
        hyperflow_k8s::chaos::ChaosConfig::parse_spec("spot:2,crash:1,pod:0.1,straggler:0.5")
            .unwrap();
    let mut data = SimConfig::with_nodes(4);
    data.data = Some(hyperflow_k8s::data::DataConfig::parse_spec("nfs:0.5,cache:4").unwrap());
    for (tag, mut cfg) in [("plain", plain), ("chaos", chaos), ("data", data)] {
        if monitor {
            cfg.monitor = Some(MonitorConfig::default());
        }
        out.push((tag, cfg));
    }
    out
}

/// Ordering-sensitive simulation fingerprint. `sim_events` is excluded
/// on purpose — `MonitorTick` calendar events are the monitor's only
/// footprint — but every workload-visible counter is included, so any
/// scrape-induced perturbation shifts at least one field.
fn fingerprint(monitor: bool) -> String {
    let mut out = String::new();
    for model in all_models() {
        for (tag, cfg) in configs(monitor) {
            let res = run(fixed_dag(), model.clone(), cfg);
            out.push_str(&format!(
                "{tag}/{}: makespan_ms={} pods={} binds={} backoffs={} api={}\n",
                model.name(),
                res.makespan.as_millis(),
                res.pods_created,
                res.sched_binds,
                res.sched_backoffs,
                res.api_requests,
            ));
        }
    }
    out
}

/// Fingerprint plus the full serialized monitor report of every run —
/// the rerun-identity check covers the alert payload itself, not just
/// the simulation counters around it.
fn monitor_fingerprint() -> String {
    let mut out = fingerprint(true);
    for model in all_models() {
        for (tag, cfg) in configs(true) {
            let res = run(fixed_dag(), model.clone(), cfg);
            let m = res
                .monitor
                .as_ref()
                .unwrap_or_else(|| panic!("{tag}/{}: monitor missing", model.name()));
            assert!(m.ticks > 0, "{tag}/{}: no scrapes", model.name());
            assert!(
                !m.alerts.is_empty(),
                "{tag}/{}: builtin rules produced no alert entries",
                model.name()
            );
            out.push_str(&format!("{tag}/{}: {}\n", model.name(), m.to_json()));
        }
    }
    out
}

#[test]
fn monitoring_does_not_perturb_the_simulation() {
    assert_eq!(
        fingerprint(false),
        fingerprint(true),
        "attaching the monitor changed the simulated trace"
    );
}

#[test]
fn monitor_runs_are_bit_identical_on_rerun() {
    assert_eq!(
        monitor_fingerprint(),
        monitor_fingerprint(),
        "monitor-on rerun diverged (alerts file must be byte-identical)"
    );
}

/// A deliberately starved chaos fleet: eight 4×4 Montage instances on a
/// two-node cluster with aggressive spot reclaims and node crashes. The
/// parallel stages pile well over 16 pods of backlog for minutes
/// (BacklogSaturation: `avg_over_time(backlog_total[120s]) > 16 for
/// 120s`), and the reclaim/crash kills burn the task-disruption error
/// budget through both the fast and the slow window.
fn starved_fleet() -> (SimResult, Vec<hyperflow_k8s::fleet::InstanceOutcome>) {
    let dags: Vec<Dag> = (0..8).map(|_| fixed_dag()).collect();
    let n = dags[0].len() as u32;
    let union = Dag::disjoint_union(&dags);
    let plan = FleetPlan {
        instances: (0..8u32)
            .map(|i| InstanceSpec {
                tenant: (i % 2) as u16,
                arrival_ms: i as u64 * 20_000,
                first_task: i * n,
                n_tasks: n,
            })
            .collect(),
        tenant_weights: vec![1, 1],
        max_in_flight: None,
    };
    let mut cfg = SimConfig::with_nodes(2);
    cfg.seed = 7;
    cfg.chaos = hyperflow_k8s::chaos::ChaosConfig::parse_spec("spot:8,crash:4,pod:0.2").unwrap();
    cfg.monitor = Some(MonitorConfig {
        interval_ms: 15_000,
        ..Default::default()
    });
    run_fleet(union, ExecModel::paper_hybrid_pools(), cfg, &plan)
}

#[test]
fn chaos_fleet_fires_the_builtin_alerts() {
    let (res, outcomes) = starved_fleet();
    assert_eq!(outcomes.len(), 8);
    let m = res.monitor.as_ref().expect("monitor attached");
    assert!(m.ticks > 10, "fleet run too short to scrape ({} ticks)", m.ticks);

    let find = |name: &str| {
        m.alerts
            .iter()
            .find(|a| a.name == name)
            .unwrap_or_else(|| panic!("builtin alert {name} missing from report"))
    };
    let backlog = find("BacklogSaturation");
    assert!(
        backlog.fired > 0,
        "BacklogSaturation never fired (episodes: {:?})",
        backlog.episodes
    );
    assert!(backlog.firing_ms > 0);
    let burn = find("TaskDisruptionBudget");
    assert!(
        burn.fired > 0,
        "TaskDisruptionBudget burn-rate never fired (episodes: {:?})",
        burn.episodes
    );

    // every episode is a well-ordered lifecycle: pending <= firing <= resolved
    for a in &m.alerts {
        for e in &a.episodes {
            if let Some(f) = e.firing_ms {
                assert!(f >= e.pending_ms, "{}: fired before pending", a.name);
                if let Some(r) = e.resolved_ms {
                    assert!(r >= f, "{}: resolved before firing", a.name);
                }
            }
        }
        for e in a.episodes.iter().take(a.episodes.len().saturating_sub(1)) {
            assert!(
                e.resolved_ms.is_some(),
                "{}: only the last episode may be open at end of run",
                a.name
            );
        }
    }
    assert!(!m.timeline().is_empty(), "firing alerts must produce a timeline");

    // the smoothed recording rules (autoscaler forecast inputs) are in
    // the report, and set_fleet installed the per-tenant SLO rules
    assert!(
        m.records.iter().any(|(n, _)| n == "backlog_forecast"),
        "holt_winters recording rule missing: {:?}",
        m.records
    );
    assert!(
        m.alerts.iter().any(|a| a.tenant == Some(1)),
        "per-tenant rules missing after set_fleet"
    );
}

#[test]
fn fleet_alert_reports_are_byte_identical_on_rerun() {
    let (a, _) = starved_fleet();
    let (b, _) = starved_fleet();
    let (ja, jb) = (
        a.monitor.expect("monitor attached").to_json().to_string(),
        b.monitor.expect("monitor attached").to_json().to_string(),
    );
    assert!(!ja.is_empty());
    assert_eq!(ja, jb, "same-seed fleet rerun must emit an identical alerts file");
}
