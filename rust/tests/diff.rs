//! Differential-analysis integration contract.
//!
//! Three properties pin the snapshot/diff design:
//!
//! 1. **Byte-determinism**: the same (workflow, model, seed) produces a
//!    byte-identical snapshot JSON string on rerun, for all four
//!    execution models — so any delta `hyperflow diff` reports is a real
//!    behavioral difference, never serialization noise.
//! 2. **Exact zero**: a self-diff reports *exactly* zero — zero makespan
//!    delta, zero in every phase, no divergence, and empty
//!    counter/gauge/alert/tenant change lists.
//! 3. **Exact telescoping**: across models (pools vs job on the fixed
//!    4×4 Montage), the seven per-phase integer-ms deltas sum exactly to
//!    the makespan delta — attribution telescopes on both sides, so the
//!    difference telescopes too.
//!
//! Plus the regression gate: an injected out-of-tolerance baseline makes
//! `hyperflow diff --bench` exit 1, and placeholder baselines disarm the
//! gate (exit 0 with a SKIPPED notice).

use hyperflow_k8s::exec::{run, ExecModel, SimConfig};
use hyperflow_k8s::fleet::{self, ArrivalProcess, FleetConfig};
use hyperflow_k8s::obs::diff::{compare_bench, diff, BenchOutcome, Tolerances};
use hyperflow_k8s::obs::snapshot;
use hyperflow_k8s::util::json::Json;
use hyperflow_k8s::workflow::dag::Dag;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn fixed_dag() -> Dag {
    generate(&MontageConfig {
        grid_w: 4,
        grid_h: 4,
        diagonals: true,
        seed: 42,
    })
}

fn all_models() -> Vec<ExecModel> {
    vec![
        ExecModel::JobBased,
        ExecModel::Clustered(hyperflow_k8s::engine::clustering::ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
        ExecModel::GenericPool,
    ]
}

fn snapshot_for(model: ExecModel) -> Json {
    let cfg = SimConfig::with_nodes(4).obs(true);
    let res = run(fixed_dag(), model, cfg.clone());
    snapshot::capture(&res, &cfg)
}

#[test]
fn snapshots_are_byte_identical_across_reruns_for_every_model() {
    for model in all_models() {
        let first = snapshot_for(model.clone()).to_string();
        let second = snapshot_for(model.clone()).to_string();
        assert_eq!(
            first, second,
            "same-seed snapshot not byte-stable under {model:?}"
        );
        let parsed = Json::parse(&first).expect("snapshot is valid JSON");
        assert_eq!(parsed.get("schema_version").unwrap().as_u64().unwrap(), 1);
        assert_eq!(parsed.get("kind").unwrap().as_str().unwrap(), "run");
        assert_eq!(parsed.get("seed").unwrap().as_u64().unwrap(), 42);
    }
}

#[test]
fn self_diff_reports_exactly_zero_for_every_model() {
    for model in all_models() {
        let snap = snapshot_for(model);
        let d = diff(&snap, &snap).unwrap();
        assert!(d.is_zero(), "self-diff must be zero: {d:?}");
        assert_eq!(d.makespan_delta_ms(), 0);
        assert_eq!(d.phase_delta_sum_ms(), 0);
        assert_eq!(d.phases.len(), 7, "all seven phases present");
        assert!(d.phases.iter().all(|p| p.delta_ms() == 0));
        assert!(d.divergence.is_none());
        assert!(d.counters.is_empty());
        assert!(d.gauges.is_empty());
        assert!(d.alerts.is_empty());
        assert!(d.tenants.is_empty());
        assert!(d.phase_tails.is_empty());
        assert!(d.warnings.is_empty(), "no provenance warnings: {:?}", d.warnings);
        let j = d.to_json();
        assert!(j.get("zero").unwrap().as_bool().unwrap());
        assert_eq!(j.get("makespan_delta_ms").unwrap().as_u64().unwrap(), 0);
    }
}

#[test]
fn cross_model_phase_deltas_sum_exactly_to_the_makespan_delta() {
    let pools = snapshot_for(ExecModel::paper_hybrid_pools());
    let job = snapshot_for(ExecModel::JobBased);
    let d = diff(&pools, &job).unwrap();
    assert!(!d.is_zero(), "pools and job must differ on the fixed DAG");
    assert_ne!(d.makespan_delta_ms(), 0);
    assert_eq!(d.phases.len(), 7);
    // the telescoping invariant in difference form: exact in integer ms
    assert_eq!(
        d.phase_delta_sum_ms(),
        d.makespan_delta_ms(),
        "phase deltas must sum exactly to the makespan delta"
    );
    // same SimConfig on both sides -> no fingerprint warning
    assert!(d.warnings.is_empty(), "unexpected warnings: {:?}", d.warnings);
    assert_ne!(d.model_a, d.model_b);
}

#[test]
fn snapshot_survives_the_text_round_trip_diff_clean() {
    // pins the CLI path: write file, read file, parse, diff
    let snap = snapshot_for(ExecModel::GenericPool);
    let reparsed = Json::parse(&format!("{snap}\n")).unwrap();
    assert_eq!(reparsed, snap);
    assert!(diff(&snap, &reparsed).unwrap().is_zero());
}

#[test]
fn fleet_snapshot_carries_tenant_rows_and_self_diffs_to_zero() {
    let cfg = SimConfig::with_nodes(4).obs(true);
    let fleet_cfg = FleetConfig {
        arrival: ArrivalProcess::Poisson { per_hour: 12.0 },
        duration_s: 900.0,
        tenants: fleet::default_tenants(2, &[3]),
        seed: 42,
        max_in_flight: None,
    };
    let res = fleet::run(ExecModel::paper_hybrid_pools(), cfg.clone(), &fleet_cfg);
    let snap = snapshot::capture_fleet(&res, &cfg);
    assert_eq!(snap.get("kind").unwrap().as_str().unwrap(), "fleet");
    let rows = snap.get("tenants").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2, "one row per tenant");
    for row in rows {
        assert!(row.get("slowdown_p99").is_ok());
        assert!(row.get("crit_compute_s").is_ok());
        assert!(row.get("alerts_fired").is_ok());
    }
    let d = diff(&snap, &snap).unwrap();
    assert!(d.is_zero());
    assert!(d.tenants.is_empty());
}

#[test]
fn committed_tolerances_parse_and_gate_injected_regressions() {
    let text = std::fs::read_to_string("baselines/tolerances.json")
        .expect("committed tolerance file readable from the crate root");
    let tol = Tolerances::parse(&Json::parse(&text).unwrap()).expect("tolerances valid");
    assert_eq!(tol.default_rel, 0.0, "deterministic metrics stay exact");
    assert!(tol.for_key("ms_per_iter") > 0.0, "wall-clock metrics get slack");

    let doc = |eps: f64, iter_ms: f64| {
        Json::parse(&format!(
            r#"{{"bench": "coordinator_hotpath", "schema_version": 1,
                 "meta": {{"git": "x", "model": "all-models", "seed": 42,
                           "config_fingerprint": "f"}},
                 "models": [{{"model": "job-based", "events_per_sec": {eps},
                              "ms_per_iter": {iter_ms}, "sim_events": 51340}}]}}"#
        ))
        .unwrap()
    };
    // within tolerance: both wall-clock metrics drift < 30%
    let ok = compare_bench(&doc(1e6, 100.0), &doc(1.2e6, 120.0), &tol);
    assert!(!ok.breached(), "in-tolerance drift must pass: {ok:?}");
    // injected regression: events_per_sec collapses 50% (> 30% band)
    let bad = compare_bench(&doc(1e6, 100.0), &doc(5e5, 100.0), &tol);
    assert!(bad.breached(), "out-of-tolerance drift must breach");
    // deterministic counter drift breaches at any size (exact default)
    let mut drifted = doc(1e6, 100.0);
    if let Json::Obj(o) = &mut drifted {
        let row = o.get_mut("models").unwrap();
        if let Json::Arr(rows) = row {
            if let Json::Obj(m) = &mut rows[0] {
                m.insert("sim_events".into(), Json::from(51341u64));
            }
        }
    }
    assert!(compare_bench(&doc(1e6, 100.0), &drifted, &tol).breached());
}

#[test]
fn committed_placeholder_baselines_disarm_the_gate() {
    for name in [
        "BENCH_driver.json",
        "BENCH_fleet.json",
        "BENCH_chaos.json",
        "BENCH_data.json",
        "BENCH_isolation.json",
    ] {
        let text = std::fs::read_to_string(format!("baselines/{name}"))
            .expect("committed baseline readable");
        let base = Json::parse(&text).expect("committed baseline is valid JSON");
        let current = Json::parse(r#"{"bench": "x", "events_per_sec": 1.0}"#).unwrap();
        let outcome = compare_bench(&base, &current, &Tolerances::default());
        // today's committed baselines are placeholders; if a future PR
        // lands measured numbers this assertion flips to Compared, which
        // is exactly when the skip notice should disappear from CI
        if base.opt("placeholder").is_some() {
            assert!(
                matches!(outcome, BenchOutcome::Skipped(_)),
                "{name}: placeholder must disarm the gate"
            );
            assert!(!outcome.breached());
        }
    }
}

/// End-to-end exit-code contract of the `hyperflow diff` subcommand.
#[test]
fn cli_diff_exit_codes_match_the_gate_contract() {
    use std::process::Command;

    let bin = env!("CARGO_BIN_EXE_hyperflow");
    let dir = std::env::temp_dir().join(format!("hf_diff_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = |name: &str| dir.join(name).to_string_lossy().into_owned();

    // two snapshots from the library (same bytes the CLI would write)
    let a = path("a.json");
    let b = path("b.json");
    let snap_a = format!("{}\n", snapshot_for(ExecModel::paper_hybrid_pools()));
    let snap_b = format!("{}\n", snapshot_for(ExecModel::JobBased));
    std::fs::write(&a, snap_a).unwrap();
    std::fs::write(&b, snap_b).unwrap();

    // self-diff and cross-diff both exit 0 (a nonzero diff is a report,
    // not an error)
    let self_diff = Command::new(bin)
        .args(["diff", a.as_str(), a.as_str()])
        .output()
        .unwrap();
    assert!(self_diff.status.success(), "self-diff must exit 0");
    let stdout = String::from_utf8_lossy(&self_diff.stdout);
    assert!(stdout.contains("observationally identical"), "{stdout}");
    let cross = Command::new(bin)
        .args(["diff", a.as_str(), b.as_str(), "--json"])
        .output()
        .unwrap();
    assert!(cross.status.success(), "cross-model diff must exit 0");
    let j = Json::parse(&String::from_utf8_lossy(&cross.stdout)).unwrap();
    assert!(!j.get("zero").unwrap().as_bool().unwrap());

    // bench gate: out-of-tolerance regression exits 1
    let base = path("base_bench.json");
    let cur = path("cur_bench.json");
    std::fs::write(&base, r#"{"bench": "t", "events_per_sec": 100.0}"#).unwrap();
    std::fs::write(&cur, r#"{"bench": "t", "events_per_sec": 10.0}"#).unwrap();
    let gate = Command::new(bin)
        .args(["diff", "--bench", base.as_str(), cur.as_str()])
        .output()
        .unwrap();
    assert_eq!(gate.status.code(), Some(1), "breach must exit 1");
    assert!(String::from_utf8_lossy(&gate.stdout).contains("FAIL"));

    // placeholder baseline exits 0 with a SKIPPED notice
    let ph = path("placeholder.json");
    std::fs::write(&ph, r#"{"bench": "t", "placeholder": true}"#).unwrap();
    let skipped = Command::new(bin)
        .args(["diff", "--bench", ph.as_str(), cur.as_str()])
        .output()
        .unwrap();
    assert!(skipped.status.success(), "placeholder must exit 0");
    assert!(String::from_utf8_lossy(&skipped.stdout).contains("SKIPPED"));

    // malformed input exits 2
    let junk = path("junk.json");
    std::fs::write(&junk, "not json").unwrap();
    let bad = Command::new(bin)
        .args(["diff", junk.as_str(), a.as_str()])
        .output()
        .unwrap();
    assert_eq!(bad.status.code(), Some(2), "malformed input must exit 2");

    std::fs::remove_dir_all(&dir).ok();
}
