//! Integration tests: full simulated executions across models, scales,
//! cluster sizes, and random DAG shapes; failure injection; and the
//! qualitative orderings the paper reports.

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::k8s::resources::Resources;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::sim::SimTime;
use hyperflow_k8s::util::ptest;
use hyperflow_k8s::util::rng::Rng;
use hyperflow_k8s::workflow::dag::Dag;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};
use hyperflow_k8s::workflow::task::{TaskId, TaskType};

fn montage(g: usize, seed: u64) -> Dag {
    generate(&MontageConfig {
        grid_w: g,
        grid_h: g,
        diagonals: true,
        seed,
    })
}

fn all_models() -> Vec<ExecModel> {
    vec![
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::Clustered(ClusteringConfig::uniform(7, 1500)),
        ExecModel::paper_hybrid_pools(),
        ExecModel::WorkerPools {
            pooled_types: vec![
                "mProject".into(),
                "mDiffFit".into(),
                "mConcatFit".into(),
                "mBgModel".into(),
                "mBackground".into(),
                "mImgtbl".into(),
                "mAdd".into(),
                "mShrink".into(),
                "mJPEG".into(),
            ],
        },
    ]
}

/// Generate a random layered DAG (not Montage-shaped) to stress the
/// engine + models with arbitrary structure.
fn random_dag(rng: &mut Rng, size: usize) -> Dag {
    let mut dag = Dag::new("random");
    let n_types = 1 + rng.below(4) as usize;
    let tys: Vec<_> = (0..n_types)
        .map(|i| {
            dag.add_type(TaskType::new(
                &format!("T{i}"),
                Resources::new(250 + rng.below(8) * 250, 256 + rng.below(8) * 256),
                0.5 + rng.f64() * 10.0,
                0.3,
            ))
        })
        .collect();
    let n = 2 + size;
    let mut ids: Vec<TaskId> = Vec::new();
    for _ in 0..n {
        let n_deps = if ids.is_empty() {
            0
        } else {
            rng.below(4.min(ids.len() as u64 + 1)) as usize
        };
        let mut deps = Vec::new();
        for _ in 0..n_deps {
            let d = ids[rng.below(ids.len() as u64) as usize];
            if !deps.contains(&d) {
                deps.push(d);
            }
        }
        let ty = tys[rng.below(tys.len() as u64) as usize];
        let dur = SimTime::from_secs_f64(0.2 + rng.f64() * 8.0);
        ids.push(dag.add_task(ty, dur, &deps));
    }
    dag
}

#[test]
fn every_model_completes_every_scale() {
    for g in [2, 5, 9] {
        for model in all_models() {
            let dag = montage(g, 7);
            let n = dag.len();
            let res = driver::run(dag, model.clone(), driver::SimConfig::with_nodes(5));
            assert_eq!(
                res.trace.records.len(),
                n,
                "{} lost tasks at g={g}",
                model.name()
            );
            assert!(res.makespan > SimTime::ZERO);
        }
    }
}

#[test]
fn paper_ordering_at_16k_scale_proxy() {
    // g=20 (~2.4k tasks) preserves the 16k orderings and runs fast
    let job = driver::run(montage(20, 42), ExecModel::JobBased, driver::SimConfig::default());
    let clu = driver::run(
        montage(20, 42),
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        driver::SimConfig::default(),
    );
    let pools = driver::run(
        montage(20, 42),
        ExecModel::paper_hybrid_pools(),
        driver::SimConfig::default(),
    );
    // makespan ordering (§4)
    assert!(clu.makespan < job.makespan);
    assert!(pools.makespan < clu.makespan);
    // utilization ordering (Figs. 3/4/6) — measured as average parallel
    // tasks (the paper's subplot metric). Allocated-CPU would credit the
    // job model for its pod-start overhead, so it is not used here.
    assert!(pools.avg_running_tasks > clu.avg_running_tasks);
    assert!(clu.avg_running_tasks > job.avg_running_tasks);
    // pod churn ordering (§3.2). pools < clustered only emerges at the
    // full 16k scale (verified by `cargo bench --bench makespan_table`);
    // at this proxy scale pool scale-up/down churn dominates.
    assert!(clu.pods_created < job.pods_created);
    assert!(pools.pods_created < job.pods_created);
    // control-plane load ordering (§3.4): both mitigations slash API load
    // relative to the job model (pools < clustered needs the full 16k
    // scale, where worker reuse amortizes; see the makespan_table bench)
    assert!(clu.api_requests < job.api_requests / 5);
    assert!(pools.api_requests < job.api_requests / 5);
    // back-off pathology is dominated by the job model
    assert!(pools.sched_backoffs < job.sched_backoffs / 2);
    assert!(clu.sched_backoffs < job.sched_backoffs / 2);
}

#[test]
fn clustering_reduces_pods_proportionally() {
    let dag = montage(10, 3);
    let diff_count = dag.count_by_type()["mDiffFit"];
    let res = driver::run(
        dag,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        driver::SimConfig::default(),
    );
    // mDiffFit clustered by 20 -> at most ceil(E/20) + partial-flush slack
    let max_expected = diff_count / 20 + diff_count / 4 + 50;
    assert!(
        (res.pods_created as usize) < max_expected + 2 * 100 + 6,
        "pods {} too many",
        res.pods_created
    );
}

#[test]
fn dependencies_hold_under_all_models_random_dags() {
    ptest::check(
        "deps-respected-random-dag",
        0xD46,
        12,
        120,
        |rng, size| {
            let dag = random_dag(rng, size);
            let model = match rng.below(3) {
                0 => ExecModel::JobBased,
                1 => ExecModel::Clustered(ClusteringConfig {
                    rules: vec![hyperflow_k8s::engine::clustering::ClusterRule {
                        match_task: vec!["T0".into()],
                        size: 1 + rng.below(6) as usize,
                        timeout_ms: 500 + rng.below(3000),
                    }],
                }),
                _ => ExecModel::WorkerPools {
                    pooled_types: dag.types.iter().map(|t| t.name.clone()).collect(),
                },
            };
            (dag, model)
        },
        |(dag_in, model)| {
            // re-run on a clone via JSON round-trip (Dag is consumed by run)
            let j = hyperflow_k8s::workflow::wfjson::to_json(dag_in);
            let dag = hyperflow_k8s::workflow::wfjson::from_json(&j).unwrap();
            let succs: Vec<(TaskId, Vec<TaskId>)> = (0..dag.len())
                .map(|i| (TaskId(i as u32), dag.successors(TaskId(i as u32)).to_vec()))
                .collect();
            let res = driver::run(dag, model.clone(), driver::SimConfig::with_nodes(3));
            for (t, ss) in succs {
                let tf = res.trace.record(t).unwrap().finished_at.unwrap();
                for s in ss {
                    let st = res.trace.record(s).unwrap().started_at.unwrap();
                    if st < tf {
                        return Err(format!("{s:?} started before {t:?} finished"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn cpu_capacity_never_exceeded_property() {
    ptest::check(
        "cpu-capacity",
        0xCAFE,
        8,
        100,
        |rng, size| (random_dag(rng, size), 1 + rng.below(6) as usize),
        |(dag_in, nodes)| {
            let j = hyperflow_k8s::workflow::wfjson::to_json(dag_in);
            let dag = hyperflow_k8s::workflow::wfjson::from_json(&j).unwrap();
            let res = driver::run(
                dag,
                ExecModel::JobBased,
                driver::SimConfig::with_nodes(*nodes),
            );
            let cap = *nodes as f64 * 4000.0;
            if let Some(s) = res.metrics.gauge("cpu_allocated_m") {
                for &(t, v) in s.points() {
                    if v > cap + 1e-9 {
                        return Err(format!("allocated {v} > capacity {cap} at t={t}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn failure_injection_still_completes() {
    for model in [
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
    ] {
        let dag = montage(6, 11);
        let n = dag.len();
        let mut cfg = driver::SimConfig::with_nodes(4);
        cfg.pod_failure_prob = 0.10; // 10% of pods crash at start
        let res = driver::run(dag, model.clone(), cfg);
        assert_eq!(res.trace.records.len(), n, "{}", model.name());
        assert!(
            res.metrics.counter("pod_failures") > 0,
            "failure injection inactive for {}",
            model.name()
        );
        // a failing cluster is not dramatically faster than a healthy one
        // (strict >= does not hold: the failure RNG perturbs batching luck)
        let healthy = driver::run(montage(6, 11), model, driver::SimConfig::with_nodes(4));
        assert!(
            res.makespan.as_secs_f64() >= healthy.makespan.as_secs_f64() * 0.8,
            "failures made the run implausibly faster"
        );
    }
}

#[test]
fn bigger_cluster_is_faster_for_pools() {
    let small = driver::run(
        montage(12, 5),
        ExecModel::paper_hybrid_pools(),
        driver::SimConfig::with_nodes(4),
    );
    let large = driver::run(
        montage(12, 5),
        ExecModel::paper_hybrid_pools(),
        driver::SimConfig::with_nodes(17),
    );
    assert!(large.makespan < small.makespan);
}

#[test]
fn makespan_lower_bounded_by_critical_path() {
    let dag = montage(8, 9);
    let cp = dag.critical_path_secs();
    for model in all_models() {
        let res = driver::run(montage(8, 9), model, driver::SimConfig::default());
        assert!(
            res.makespan.as_secs_f64() >= cp,
            "makespan {} below critical path {cp}",
            res.makespan.as_secs_f64()
        );
    }
}

#[test]
fn result_json_round_trips() {
    let res = driver::run(
        montage(4, 2),
        ExecModel::paper_hybrid_pools(),
        driver::SimConfig::with_nodes(3),
    );
    let j = res.to_json().to_string();
    let parsed = hyperflow_k8s::util::json::Json::parse(&j).unwrap();
    assert_eq!(
        parsed.get("model").unwrap().as_str().unwrap(),
        "worker-pools"
    );
    assert!(parsed.get("makespan_s").unwrap().as_f64().unwrap() > 0.0);
    let csv = res.utilization_csv();
    assert!(csv.lines().count() > 2);
    assert!(csv.starts_with("t_s,running_tasks"));
}

#[test]
fn pool_queues_drain_to_zero() {
    let res = driver::run(
        montage(8, 13),
        ExecModel::paper_hybrid_pools(),
        driver::SimConfig::default(),
    );
    for pool in ["mProject", "mDiffFit", "mBackground"] {
        let q = res.metrics.gauge(&format!("queue::{pool}")).unwrap();
        assert_eq!(q.last_value(), 0.0, "{pool} queue not drained");
        assert!(q.max_value() > 0.0, "{pool} queue never used");
    }
}

#[test]
fn deterministic_across_runs_all_models() {
    for model in all_models() {
        let a = driver::run(montage(5, 21), model.clone(), driver::SimConfig::default());
        let b = driver::run(montage(5, 21), model, driver::SimConfig::default());
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.pods_created, b.pods_created);
        assert_eq!(a.sched_backoffs, b.sched_backoffs);
    }
}
