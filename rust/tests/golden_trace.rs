//! Cross-model golden-trace fixture: machine-checked parity for the
//! kernel/strategy refactor.
//!
//! All four execution models run on one fixed small Montage DAG, plain
//! and with the chaos / data / fleet subsystems attached, and the
//! resulting fingerprint — makespan ms, event count, pods, scheduler
//! binds/back-offs per configuration — is compared line-by-line against
//! the committed snapshot in `tests/golden/exec_trace.txt`.
//!
//! Snapshot lifecycle:
//! * If the snapshot file exists, any mismatch is a hard failure — an
//!   event-ordering change slipped in.
//! * If it does not exist yet (fresh checkout on a machine that never ran
//!   the suite), the test *materializes* it from the current build and
//!   passes; the second run — CI runs this test twice — verifies against
//!   the freshly-written file, pinning within-build stability. Committing
//!   the generated file then pins the fingerprint across future PRs.
//! * `HF_GOLDEN_REWRITE=1` rewrites the snapshot deliberately (only after
//!   an *intentional* behavior change, with the diff called out in the
//!   PR).

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::exec::{run, run_fleet, ExecModel, SimConfig};
use hyperflow_k8s::fleet::{FleetPlan, InstanceSpec};
use hyperflow_k8s::workflow::dag::Dag;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn fixed_dag() -> Dag {
    generate(&MontageConfig {
        grid_w: 4,
        grid_h: 4,
        diagonals: true,
        seed: 11,
    })
}

fn all_models() -> Vec<ExecModel> {
    vec![
        ExecModel::JobBased,
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        ExecModel::paper_hybrid_pools(),
        ExecModel::GenericPool,
    ]
}

/// One fingerprint line: every counter here is ordering-sensitive, so a
/// single same-timestamp FIFO violation anywhere in the refactored event
/// loop shifts at least one of them.
fn line(tag: &str, model: &ExecModel, res: &hyperflow_k8s::report::SimResult) -> String {
    format!(
        "{tag}/{}: makespan_ms={} events={} pods={} binds={} backoffs={} api={}",
        model.name(),
        res.makespan.as_millis(),
        res.sim_events,
        res.pods_created,
        res.sched_binds,
        res.sched_backoffs,
        res.api_requests,
    )
}

fn fingerprint() -> String {
    let mut out = String::new();
    for model in all_models() {
        // plain: the paper's healthy-cluster harness
        let res = run(fixed_dag(), model.clone(), SimConfig::with_nodes(4));
        out.push_str(&line("plain", &model, &res));
        out.push('\n');
        // chaos: every injector class at a fixed seed
        let mut cfg = SimConfig::with_nodes(4);
        cfg.seed = 7;
        cfg.chaos = hyperflow_k8s::chaos::ChaosConfig::parse_spec(
            "spot:2,crash:1,pod:0.1,straggler:0.5",
        )
        .unwrap();
        let res = run(fixed_dag(), model.clone(), cfg);
        out.push_str(&line("chaos", &model, &res));
        out.push('\n');
        // data: constrained shared NFS with warm caches
        let mut cfg = SimConfig::with_nodes(4);
        cfg.data =
            Some(hyperflow_k8s::data::DataConfig::parse_spec("nfs:0.5,cache:4").unwrap());
        let res = run(fixed_dag(), model.clone(), cfg);
        out.push_str(&line("data", &model, &res));
        out.push('\n');
        // fleet: two staggered instances through admission + tenant lanes
        let (a, b) = (fixed_dag(), fixed_dag());
        let (n_a, n_b) = (a.len() as u32, b.len() as u32);
        let union = Dag::disjoint_union(&[a, b]);
        let plan = FleetPlan {
            instances: vec![
                InstanceSpec {
                    tenant: 0,
                    arrival_ms: 0,
                    first_task: 0,
                    n_tasks: n_a,
                },
                InstanceSpec {
                    tenant: 1,
                    arrival_ms: 20_000,
                    first_task: n_a,
                    n_tasks: n_b,
                },
            ],
            tenant_weights: vec![2, 1],
            max_in_flight: None,
        };
        let (res, outcomes) = run_fleet(union, model.clone(), SimConfig::with_nodes(4), &plan);
        assert_eq!(outcomes.len(), 2, "{}: fleet outcomes", model.name());
        out.push_str(&line("fleet", &model, &res));
        out.push('\n');
    }
    out
}

#[test]
fn golden_trace_matches_committed_snapshot() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/exec_trace.txt");
    let current = fingerprint();
    let rewrite = std::env::var("HF_GOLDEN_REWRITE").ok().as_deref() == Some("1");
    match std::fs::read_to_string(path) {
        Ok(golden) if !rewrite => {
            let golden_lines: Vec<&str> = golden.lines().collect();
            let current_lines: Vec<&str> = current.lines().collect();
            assert_eq!(
                golden_lines.len(),
                current_lines.len(),
                "golden snapshot shape changed: {} vs {} lines \
                 (rerun with HF_GOLDEN_REWRITE=1 only for an intentional change)",
                golden_lines.len(),
                current_lines.len()
            );
            for (g, c) in golden_lines.iter().zip(&current_lines) {
                assert_eq!(
                    g, c,
                    "golden trace diverged — event ordering or accounting changed \
                     (rerun with HF_GOLDEN_REWRITE=1 only for an intentional change)"
                );
            }
        }
        _ => {
            std::fs::create_dir_all(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden"))
                .expect("create tests/golden");
            std::fs::write(path, &current).expect("write golden snapshot");
            eprintln!(
                "golden_trace: materialized {} ({} lines) — commit it to pin the fingerprint",
                path,
                current.lines().count()
            );
        }
    }
}

/// Independent of the snapshot file: the fingerprint itself must be
/// stable within one build (two full sweeps, identical strings).
#[test]
fn golden_fingerprint_is_reproducible_in_process() {
    assert_eq!(fingerprint(), fingerprint(), "rerun fingerprint diverged");
}
