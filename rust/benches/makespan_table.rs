//! Regenerates the §4.4 headline comparison: makespans of the three
//! execution models on the 16k-task Montage, plus Table-1 ablations
//! quantifying each workflow-characteristic challenge.
//!
//!   cargo bench --bench makespan_table
//!
//! Writes bench_out/makespan_table.csv.

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::k8s::scheduler::SchedulerConfig;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::report::{figures, write_output};
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    println!("§4.4 makespan comparison — 16k-task Montage, 17 nodes (68 cores)\n");
    println!(
        "{:>30} {:>10} {:>8} {:>10} {:>10} {:>9} {:>9}",
        "model", "makespan", "pods", "api reqs", "backoffs", "cpu util", "parallel"
    );
    let rows = figures::makespan_table();
    let mut csv =
        String::from("model,makespan_s,pods,api_requests,backoffs,cpu_util,avg_parallel\n");
    for r in &rows {
        println!(
            "{:>30} {:>9.0}s {:>8} {:>10} {:>10} {:>8.1}% {:>9.1}",
            r.label, r.makespan_s, r.pods, r.api_requests, r.backoffs,
            r.cpu_util * 100.0, r.avg_parallel
        );
        csv.push_str(&format!(
            "{},{:.0},{},{},{},{:.3},{:.1}\n",
            r.label, r.makespan_s, r.pods, r.api_requests, r.backoffs, r.cpu_util,
            r.avg_parallel
        ));
    }
    let pools = rows.last().unwrap().makespan_s;
    let best_job = rows[..rows.len() - 1]
        .iter()
        .map(|r| r.makespan_s)
        .fold(f64::INFINITY, f64::min);
    println!(
        "\nheadline: worker pools {:.0}s vs best job-based {:.0}s -> {:.1}% improvement",
        pools,
        best_job,
        (best_job - pools) / best_job * 100.0
    );
    println!("paper:    worker pools ~1420s vs best job-based ~1700s -> \"nearly 20%\"\n");

    // ---- Table 1 ablations: quantify each execution challenge ----------
    println!("Table 1 ablations (workflow characteristic -> measured impact)\n");
    let wf = MontageConfig {
        grid_w: 20,
        grid_h: 20,
        diagonals: true,
        seed: 42,
    };

    // (a) short tasks + pod churn: job model pays pod_start per task
    let base = driver::run(generate(&wf), ExecModel::JobBased, figures::paper_sim_config());
    let mut fast = figures::paper_sim_config();
    fast.pod_start_ms = 0;
    let nostart = driver::run(generate(&wf), ExecModel::JobBased, fast);
    println!(
        "  pod-creation overhead: job-model makespan {:.0}s with 2s pod start vs {:.0}s with 0s (+{:.0}%)",
        base.makespan.as_secs_f64(),
        nostart.makespan.as_secs_f64(),
        (base.makespan.as_secs_f64() / nostart.makespan.as_secs_f64() - 1.0) * 100.0
    );

    // (b) back-off delays: job model with no scheduler back-off growth
    let mut noback = figures::paper_sim_config();
    noback.sched = SchedulerConfig {
        backoff_initial_ms: 500,
        backoff_max_ms: 500,
        ..Default::default()
    };
    let nb = driver::run(generate(&wf), ExecModel::JobBased, noback);
    println!(
        "  exponential back-off:  job-model makespan {:.0}s with back-off vs {:.0}s with 0.5s flat retry (+{:.0}%)",
        base.makespan.as_secs_f64(),
        nb.makespan.as_secs_f64(),
        (base.makespan.as_secs_f64() / nb.makespan.as_secs_f64() - 1.0) * 100.0
    );

    // (c) proportional allocation: pools with vs without intertwined stages
    let pools_run = driver::run(
        generate(&wf),
        ExecModel::paper_hybrid_pools(),
        figures::paper_sim_config(),
    );
    println!(
        "  intertwined stages:    pools keep cpu util at {:.0}% (job model: {:.0}%)",
        pools_run.avg_cpu_utilization * 100.0,
        base.avg_cpu_utilization * 100.0
    );

    // (d) clustering sensitivity (short tasks): size 1 vs paper cfg
    let clu = driver::run(
        generate(&wf),
        ExecModel::Clustered(ClusteringConfig::paper_default()),
        figures::paper_sim_config(),
    );
    println!(
        "  short tasks:           clustering cuts pods {} -> {} and makespan {:.0}s -> {:.0}s",
        base.pods_created,
        clu.pods_created,
        base.makespan.as_secs_f64(),
        clu.makespan.as_secs_f64()
    );

    // ---- design-choice ablations (§3.3, §3.5, §5) -----------------------
    println!("\nDesign ablations (16k workflow unless noted)\n");

    // (e) §3.3: single generic worker pool vs typed pools
    let wf16 = MontageConfig::paper_16k();
    let generic = driver::run(
        generate(&wf16),
        ExecModel::GenericPool,
        figures::paper_sim_config(),
    );
    println!(
        "  generic single pool:   {:.0}s at {:.0}% util (typed hybrid pools: {:.0}s at {:.0}%) — \
         \"inferior ... degrades scheduling quality\"",
        generic.makespan.as_secs_f64(),
        generic.avg_cpu_utilization * 100.0,
        pools,
        rows.last().unwrap().cpu_util * 100.0
    );

    // (f) §5 future work: throttled job submission fixes the job model
    let mut thr = figures::paper_sim_config();
    thr.max_pending_pods = Some(64);
    let throttled = driver::run(generate(&wf16), ExecModel::JobBased, thr);
    println!(
        "  throttled job model:   {:.0}s with <=64 pending pods vs {:.0}s unthrottled \
         (backoffs {} vs {})",
        throttled.makespan.as_secs_f64(),
        rows[0].makespan_s,
        throttled.sched_backoffs,
        rows[0].backoffs
    );

    // (g) §3.5: KEDA scale-to-zero vs plain HPA (min 1 replica per pool)
    let mut hpa = figures::paper_sim_config();
    hpa.autoscale.min_replicas = 1;
    let hpa_run = driver::run(generate(&wf16), ExecModel::paper_hybrid_pools(), hpa);
    println!(
        "  plain HPA (min 1):     {:.0}s vs KEDA scale-to-zero {:.0}s — idle pools hold slots",
        hpa_run.makespan.as_secs_f64(),
        pools
    );

    // (h) §5 future work: vertical pod autoscaling (right-size workers to
    // observed usage after 20 samples)
    let mut vpa = figures::paper_sim_config();
    vpa.autoscale.vpa = true;
    let vpa_run = driver::run(generate(&wf16), ExecModel::paper_hybrid_pools(), vpa);
    println!(
        "  VPA right-sizing:      {:.0}s vs {:.0}s without — observed-usage requests pack more workers",
        vpa_run.makespan.as_secs_f64(),
        pools
    );

    // (i) §5 future work: multi-cloud execution (fixed 16-node capacity,
    // split across 1/2/4 clusters, 500 ms per cross-cloud dependency)
    use hyperflow_k8s::models::multicloud::{self, McConfig, McMode};
    println!("\nMulti-cloud (§5 future work): same capacity, more clusters (20x20 Montage)\n");
    for clusters in [vec![16], vec![8, 8], vec![4, 4, 4, 4]] {
        let label = clusters
            .iter()
            .map(|n| n.to_string())
            .collect::<Vec<_>>()
            .join("+");
        let r = multicloud::run(
            generate(&wf),
            McConfig {
                clusters: clusters.clone(),
                mode: McMode::Pools,
                transfer_ms_per_dep: 500,
                ..Default::default()
            },
        );
        println!(
            "  clusters {label:>8}: makespan {:>6.0}s  cross-cloud transfers {:>7}  tasks/cloud {:?}",
            r.makespan.as_secs_f64(),
            r.transfers,
            r.tasks_per_cloud
        );
    }

    let path = write_output("makespan_table.csv", &csv).unwrap();
    println!("\nwrote {path}");
    println!("bench wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
