//! Data-plane sweep: re-run the paper's model comparison under realistic
//! I/O pressure — NFS bandwidth x backend x execution model — and record,
//! per point, the makespan, bytes moved, cache hit ratio, stage-in tail
//! and I/O share. The headline is the warm-cache asymmetry: long-lived
//! pool workers keep node-local caches across tasks, job pods always
//! start cold, so at constrained NFS bandwidth worker-pools beat the job
//! model on bytes moved and stage-in p95 (see EXPERIMENTS.md §"Data
//! plane / storage" for how to read the knee).
//!
//! Results are written to `BENCH_data.json` (crate root, next to
//! `BENCH_driver.json`, `BENCH_fleet.json` and `BENCH_chaos.json`).
//!
//!   cargo bench --bench data_locality
//!
//! CI runs a reduced sweep: `HF_DATA_GRID=4 HF_DATA_RATES=0.5,2`.
//! `HF_DATA_RATES=0.25,1,...` overrides the swept NFS bandwidths
//! (Gbit/s); `HF_DATA_CACHE_GB` the per-node cache size.

use hyperflow_k8s::data::DataConfig;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::util::env::{env_f64, env_f64_list, env_usize};
use hyperflow_k8s::util::json::Json;
use hyperflow_k8s::util::sweep;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn main() {
    let nodes = env_usize("HF_DATA_NODES", 4);
    let grid = env_usize("HF_DATA_GRID", 6);
    let cache_gb = env_f64("HF_DATA_CACHE_GB", 4.0);
    let seed: u64 = 42;
    let rates = env_f64_list("HF_DATA_RATES", &[0.25, 0.5, 1.0, 2.0, 5.0]);

    let models: Vec<(&str, ExecModel)> = vec![
        ("job-based", ExecModel::JobBased),
        ("worker-pools", ExecModel::paper_hybrid_pools()),
    ];
    // NFS points sweep the aggregate server bandwidth; one object-store
    // point anchors the per-request-latency regime for comparison.
    let mut backends: Vec<(String, String)> = rates
        .iter()
        .map(|r| (format!("nfs:{r}"), format!("nfs:{r},cache:{cache_gb}")))
        .collect();
    backends.push(("s3:30x1".into(), format!("s3:30x1,cache:{cache_gb}")));

    let mk_dag = || {
        generate(&MontageConfig {
            grid_w: grid,
            grid_h: grid,
            diagonals: true,
            seed,
        })
    };

    println!(
        "== data locality sweep == ({nodes} nodes, montage {grid}x{grid}, \
         NFS rates {rates:?} Gbit/s + s3, cache {cache_gb} GB/node, seed {seed})\n"
    );
    // flatten the model x (baseline + backends) grid into independent
    // sweep points (each a self-contained seeded run) and fan out across
    // HF_BENCH_THREADS; collection order is point order, so the printed
    // report and BENCH_data.json stay byte-identical to the serial loop
    let mut grid_pts: Vec<(usize, Option<usize>)> = Vec::new();
    for m in 0..models.len() {
        grid_pts.push((m, None));
        for b in 0..backends.len() {
            grid_pts.push((m, Some(b)));
        }
    }
    let results = sweep::run(grid_pts, |_, (m, backend)| {
        let mut cfg = driver::SimConfig::with_nodes(nodes);
        cfg.seed = seed;
        if let Some(b) = backend {
            cfg.max_sim_s = 24.0 * 3600.0; // starved links stretch runs
            cfg.data = Some(DataConfig::parse_spec(&backends[b].1).expect("bench data spec"));
        }
        let res = driver::run(mk_dag(), models[m].1.clone(), cfg);
        (res.makespan.as_secs_f64(), res.data)
    });
    let stride = 1 + backends.len();
    let mut model_rows: Vec<Json> = Vec::new();
    for (m, (name, _)) in models.iter().enumerate() {
        let base_s = results[m * stride].0;
        println!("{name}: no-data makespan {base_s:.0}s");
        let mut points: Vec<Json> = Vec::new();
        for (b, (label, spec)) in backends.iter().enumerate() {
            let (makespan_s, d) = &results[m * stride + 1 + b];
            let makespan_s = *makespan_s;
            println!(
                "  {label:>10}: makespan {makespan_s:>7.0}s (x{:>5.2})  moved {:>6.2} GB  \
                 hits {:>5.1}%  stage-in p50/p95/p99 {:>5.2}/{:>5.2}/{:>6.2}s  io {:>4.1}%",
                makespan_s / base_s,
                d.bytes_moved() as f64 / 1e9,
                d.cache_hit_ratio() * 100.0,
                d.stage_in_p50_s,
                d.stage_in_p95_s,
                d.stage_in_p99_s,
                d.io_frac() * 100.0,
            );
            points.push(Json::obj(vec![
                ("backend", Json::str(label)),
                ("data_spec", Json::str(spec)),
                ("makespan_s", makespan_s.into()),
                ("makespan_inflation", (makespan_s / base_s).into()),
                ("bytes_in", d.bytes_in.into()),
                ("bytes_out", d.bytes_out.into()),
                ("bytes_moved", d.bytes_moved().into()),
                ("cache_hit_ratio", d.cache_hit_ratio().into()),
                ("evictions", d.evictions.into()),
                ("transfers", d.transfers.into()),
                ("stage_in_p50_s", d.stage_in_p50_s.into()),
                ("stage_in_p95_s", d.stage_in_p95_s.into()),
                ("stage_in_p99_s", d.stage_in_p99_s.into()),
                ("io_frac", d.io_frac().into()),
            ]));
        }
        println!();
        model_rows.push(Json::obj(vec![
            ("model", Json::str(name)),
            ("baseline_makespan_s", base_s.into()),
            ("points", Json::Arr(points)),
        ]));
    }

    let mut meta_cfg = driver::SimConfig::with_nodes(nodes);
    meta_cfg.seed = seed;
    let out = Json::obj(vec![
        ("bench", Json::str("data_locality")),
        ("schema_version", hyperflow_k8s::util::meta::BENCH_SCHEMA_VERSION.into()),
        (
            "meta",
            hyperflow_k8s::util::meta::bench_meta("all-models", seed, &meta_cfg.fingerprint()),
        ),
        ("nodes", nodes.into()),
        ("grid", grid.into()),
        ("cache_gb", cache_gb.into()),
        ("seed", seed.into()),
        (
            "nfs_rates_gbps",
            Json::Arr(rates.iter().map(|&r| r.into()).collect()),
        ),
        ("models", Json::Arr(model_rows)),
    ]);
    let path = "BENCH_data.json";
    match std::fs::write(path, out.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
