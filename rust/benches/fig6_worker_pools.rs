//! Regenerates Fig. 6: the hybrid worker-pools model on the 16k Montage.
//! The paper: "The cluster utilization is consistently high for all
//! parallel stages of the workflow, reaching the maximum capacity of the
//! cluster" with makespan ≈ 1420 s (vs ≈ 1700 s for the best job model).
//!
//!   cargo bench --bench fig6_worker_pools
//!
//! Writes bench_out/fig6_utilization.csv and bench_out/fig6.json.

use hyperflow_k8s::report::{figures, write_output};
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let (res, _wf, text) = figures::fig6_worker_pools();
    println!("{text}");

    // shape checks from §4.4
    let peak = res
        .running_series()
        .iter()
        .map(|&(_, v)| v)
        .fold(0.0f64, f64::max);
    println!(
        "peak parallel tasks: {peak:.0} (cluster capacity: 136 slots of 500m on 68 cores)"
    );
    println!(
        "avg cpu utilization: {:.1}%  (paper: \"consistently high ... reaching the maximum capacity\")",
        res.avg_cpu_utilization * 100.0
    );
    let csv = write_output("fig6_utilization.csv", &res.utilization_csv()).unwrap();
    let json = write_output("fig6.json", &res.to_json().to_string()).unwrap();
    println!("wrote {csv}, {json}");
    println!("bench wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
