//! Fleet saturation sweep: drive the open-loop multi-tenant service past
//! its saturation knee and record, per arrival rate, the completed
//! throughput (instances/hour), mean/p99 slowdown, queueing delay and
//! cluster utilization. Below the knee utilization grows linearly with
//! offered load while slowdown stays near its floor; past it, utilization
//! plateaus at capacity and slowdown diverges — see EXPERIMENTS.md
//! §"Fleet / multi-tenant service" for how to read the output.
//!
//! Results are written to `BENCH_fleet.json` (crate root, next to
//! `BENCH_driver.json`) so the service-capacity trajectory is tracked
//! across PRs.
//!
//!   cargo bench --bench fleet_saturation
//!
//! CI runs a reduced sweep: `HF_FLEET_DURATION=400 HF_FLEET_NODES=4`.
//! `HF_FLEET_RATES=20,60,...` overrides the swept arrival rates.

use hyperflow_k8s::fleet::{self, ArrivalProcess, FleetConfig};
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::util::env::{env_f64, env_f64_list, env_usize};
use hyperflow_k8s::util::json::Json;
use hyperflow_k8s::util::sweep;

fn main() {
    let nodes = env_usize("HF_FLEET_NODES", 4);
    let duration = env_f64("HF_FLEET_DURATION", 1800.0);
    let tenants = env_usize("HF_FLEET_TENANTS", 4);
    let rates = env_f64_list("HF_FLEET_RATES", &[15.0, 30.0, 60.0, 90.0, 120.0]);

    println!(
        "== fleet saturation sweep == ({nodes} nodes, {duration:.0}s arrival window, \
         {tenants} tenants, worker-pools)\n"
    );
    // each rate is an independent seeded simulation: fan out across
    // HF_BENCH_THREADS workers, print from the collected results so the
    // output (and BENCH_fleet.json) is byte-identical to the serial run
    let aggs = sweep::run(rates.clone(), |_, rate| {
        let cfg = FleetConfig {
            arrival: ArrivalProcess::Poisson { per_hour: rate },
            duration_s: duration,
            tenants: fleet::default_tenants(tenants, &[4, 5]),
            seed: 42,
            max_in_flight: None,
        };
        let res = fleet::run(
            ExecModel::paper_hybrid_pools(),
            driver::SimConfig::with_nodes(nodes),
            &cfg,
        );
        fleet::report::aggregate(&res)
    });
    let mut points: Vec<Json> = Vec::new();
    for (&rate, agg) in rates.iter().zip(&aggs) {
        println!(
            "rate {rate:>6.1}/h: {:>4} instances  throughput {:>6.1}/h  util {:>5.1}%  \
             slowdown mean {:>7.2} p99 {:>8.2}  qdelay {:>6.1}s",
            agg.instances,
            agg.completed_per_hour,
            agg.utilization * 100.0,
            agg.mean_slowdown,
            agg.slowdown_p99,
            agg.mean_queue_delay_s,
        );
        points.push(Json::obj(vec![
            ("arrival_rate_per_hour", rate.into()),
            ("instances", agg.instances.into()),
            ("instances_per_hour", agg.completed_per_hour.into()),
            ("mean_slowdown", agg.mean_slowdown.into()),
            ("slowdown_p99", agg.slowdown_p99.into()),
            ("mean_queue_delay_s", agg.mean_queue_delay_s.into()),
            ("utilization", agg.utilization.into()),
            ("span_s", agg.span_s.into()),
        ]));
    }

    let out = Json::obj(vec![
        ("bench", Json::str("fleet_saturation")),
        ("schema_version", hyperflow_k8s::util::meta::BENCH_SCHEMA_VERSION.into()),
        (
            "meta",
            hyperflow_k8s::util::meta::bench_meta(
                "worker-pools",
                42,
                &driver::SimConfig::with_nodes(nodes).fingerprint(),
            ),
        ),
        ("model", Json::str("worker-pools")),
        ("nodes", nodes.into()),
        ("duration_s", duration.into()),
        ("tenants", tenants.into()),
        ("seed", 42u64.into()),
        ("points", Json::Arr(points)),
    ]);
    let path = "BENCH_fleet.json";
    match std::fs::write(path, out.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }
}
