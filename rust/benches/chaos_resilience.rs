//! Chaos resilience sweep: re-run the paper's model comparison under
//! increasing spot-reclaim churn and record, per model per reclaim rate,
//! the makespan inflation over the healthy baseline, the wasted-work
//! fraction, goodput, and the recovery counters. This answers the question
//! the paper leaves open: *which execution model degrades gracefully* when
//! the cluster is preemptible (see EXPERIMENTS.md §"Resilience / chaos"
//! for how to read the curves).
//!
//! Each sweep point runs with the chaos spec
//! `spot:<R>,crash:<R/2>,pod:0.03,straggler:0.25` — the reclaim rate R is
//! the swept variable; the fixed low-grade crash/pod/straggler background
//! keeps the wasted-work accounting exercised even at reclaim rates whose
//! two-minute drain warning lets most in-flight tasks finish.
//!
//! Results are written to `BENCH_chaos.json` (crate root, next to
//! `BENCH_driver.json` and `BENCH_fleet.json`).
//!
//!   cargo bench --bench chaos_resilience
//!
//! CI runs a reduced grid: `HF_CHAOS_GRID=4 HF_CHAOS_RATES=2,4,8`.

use hyperflow_k8s::chaos::ChaosConfig;
use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::util::env::{env_f64_list, env_usize};
use hyperflow_k8s::util::json::Json;
use hyperflow_k8s::util::sweep;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};

fn main() {
    let nodes = env_usize("HF_CHAOS_NODES", 4);
    let grid = env_usize("HF_CHAOS_GRID", 6);
    let seed: u64 = 42;
    let rates = env_f64_list("HF_CHAOS_RATES", &[1.0, 2.0, 4.0, 8.0]);

    let models: Vec<(&str, ExecModel)> = vec![
        ("job-based", ExecModel::JobBased),
        (
            "job-clustered",
            ExecModel::Clustered(ClusteringConfig::paper_default()),
        ),
        ("worker-pools", ExecModel::paper_hybrid_pools()),
        ("generic-pool", ExecModel::GenericPool),
    ];

    let mk_dag = || {
        generate(&MontageConfig {
            grid_w: grid,
            grid_h: grid,
            diagonals: true,
            seed,
        })
    };
    let mk_cfg = |spec: Option<&str>| {
        let mut cfg = driver::SimConfig::with_nodes(nodes);
        cfg.seed = seed;
        cfg.max_sim_s = 24.0 * 3600.0; // heavy churn can stretch far past 6h
        if let Some(spec) = spec {
            cfg.chaos = ChaosConfig::parse_spec(spec).expect("bench chaos spec");
        }
        cfg
    };

    println!(
        "== chaos resilience sweep == ({nodes} nodes, montage {grid}x{grid}, \
         reclaim rates {rates:?}/node/h, seed {seed})\n"
    );
    // flatten the model x (baseline + rates) grid into independent sweep
    // points; each is a self-contained seeded run, so the fan-out leaves
    // output and BENCH_chaos.json byte-identical to the serial loop
    let mut grid_pts: Vec<(usize, Option<f64>)> = Vec::new();
    for m in 0..models.len() {
        grid_pts.push((m, None));
        for &rate in &rates {
            grid_pts.push((m, Some(rate)));
        }
    }
    let results = sweep::run(grid_pts, |_, (m, rate)| {
        let spec =
            rate.map(|r| format!("spot:{r},crash:{},pod:0.03,straggler:0.25", r / 2.0));
        let res = driver::run(mk_dag(), models[m].1.clone(), mk_cfg(spec.as_deref()));
        (res.makespan.as_secs_f64(), res.chaos)
    });
    let stride = 1 + rates.len();
    let mut model_rows: Vec<Json> = Vec::new();
    for (m, (name, _)) in models.iter().enumerate() {
        let base_s = results[m * stride].0;
        println!("{name}: healthy makespan {base_s:.0}s");
        let mut points: Vec<Json> = Vec::new();
        for (ri, &rate) in rates.iter().enumerate() {
            let spec = format!("spot:{rate},crash:{},pod:0.03,straggler:0.25", rate / 2.0);
            let (makespan_s, c) = &results[m * stride + 1 + ri];
            let makespan_s = *makespan_s;
            let inflation = makespan_s / base_s;
            println!(
                "  reclaim {rate:>5.1}/h: makespan {makespan_s:>7.0}s (x{inflation:>5.2})  \
                 wasted {:>6.1}% goodput {:>5.1}%  faults {:>4} retries {:>4} spec {:>3}",
                c.wasted_frac() * 100.0,
                c.goodput() * 100.0,
                c.faults_total(),
                c.retries,
                c.speculations,
            );
            points.push(Json::obj(vec![
                ("reclaim_rate_per_node_per_hour", rate.into()),
                ("chaos_spec", Json::str(&spec)),
                ("makespan_s", makespan_s.into()),
                ("makespan_inflation", inflation.into()),
                ("wasted_work_frac", c.wasted_frac().into()),
                ("goodput", c.goodput().into()),
                ("wasted_ms", c.wasted_ms.into()),
                ("useful_ms", c.useful_ms.into()),
                ("faults_total", c.faults_total().into()),
                ("spot_reclaims", c.spot_reclaims.into()),
                ("node_crashes", c.node_crashes.into()),
                ("pod_failures", c.pod_failures.into()),
                ("retries", c.retries.into()),
                ("speculations", c.speculations.into()),
                ("recovery_p95_s", c.recovery_p95_s.into()),
            ]));
        }
        println!();
        model_rows.push(Json::obj(vec![
            ("model", Json::str(name)),
            ("baseline_makespan_s", base_s.into()),
            ("points", Json::Arr(points)),
        ]));
    }

    let out = Json::obj(vec![
        ("bench", Json::str("chaos_resilience")),
        ("schema_version", hyperflow_k8s::util::meta::BENCH_SCHEMA_VERSION.into()),
        (
            "meta",
            hyperflow_k8s::util::meta::bench_meta("all-models", seed, &mk_cfg(None).fingerprint()),
        ),
        ("nodes", nodes.into()),
        ("grid", grid.into()),
        ("seed", seed.into()),
        (
            "reclaim_rates_per_node_per_hour",
            Json::Arr(rates.iter().map(|&r| r.into()).collect()),
        ),
        ("models", Json::Arr(model_rows)),
    ]);
    let path = "BENCH_chaos.json";
    match std::fs::write(path, out.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
