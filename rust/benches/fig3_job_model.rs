//! Regenerates Fig. 3: the job-based model on the smaller Montage workflow.
//! The paper's observation — "the execution collapses [...] the cluster
//! remains hardly utilized for most of the execution" — shows up as low
//! average utilization and a back-off count comparable to the task count.
//!
//!   cargo bench --bench fig3_job_model
//!
//! Writes bench_out/fig3_utilization.csv and bench_out/fig3.json.

use hyperflow_k8s::report::{figures, write_output};
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let (res, _wf, text) = figures::fig3_job_model();
    println!("{text}");
    println!(
        "paper shape check: avg utilization LOW ({:.0}% cpu), back-offs {} for {} pods",
        res.avg_cpu_utilization * 100.0,
        res.sched_backoffs,
        res.pods_created
    );
    let csv = write_output("fig3_utilization.csv", &res.utilization_csv()).unwrap();
    let json = write_output("fig3.json", &res.to_json().to_string()).unwrap();
    println!("wrote {csv}, {json}");
    println!("bench wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
