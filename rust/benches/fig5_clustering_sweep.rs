//! Regenerates Fig. 5: the clustering-parameter sweep. The paper "tried
//! multiple combinations for task agglomeration parameters with different
//! outcomes [...] no configuration has produced entirely satisfactory
//! results" — i.e. every configuration leaves utilization gaps and none
//! reaches the worker-pools makespan.
//!
//!   cargo bench --bench fig5_clustering_sweep
//!
//! Writes bench_out/fig5_sweep.csv.

use hyperflow_k8s::report::{figures, write_output};
use hyperflow_k8s::util::ascii_plot;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let runs = figures::fig5_sweep();
    println!("Fig. 5 — clustering parameter sweep, 16k Montage, 17 nodes\n");
    println!(
        "{:>18} {:>10} {:>8} {:>10} {:>10}",
        "config", "makespan", "pods", "backoffs", "cpu util"
    );
    let mut csv = String::from("config,makespan_s,pods,backoffs,cpu_util\n");
    for (label, res) in &runs {
        println!(
            "{label:>18} {:>9.0}s {:>8} {:>10} {:>9.1}%",
            res.makespan.as_secs_f64(),
            res.pods_created,
            res.sched_backoffs,
            res.avg_cpu_utilization * 100.0
        );
        csv.push_str(&format!(
            "{label},{:.0},{},{},{:.3}\n",
            res.makespan.as_secs_f64(),
            res.pods_created,
            res.sched_backoffs,
            res.avg_cpu_utilization
        ));
    }
    println!();
    for (label, res) in runs.iter().take(4) {
        println!(
            "{}",
            ascii_plot::area_chart(
                &format!("  {label} — tasks running"),
                &res.running_series(),
                100,
                6
            )
        );
    }
    // the paper's conclusion: no configuration is "entirely satisfactory"
    let best = runs
        .iter()
        .map(|(_, r)| r.makespan.as_secs_f64())
        .fold(f64::INFINITY, f64::min);
    println!("best clustering makespan: {best:.0}s — all configs leave gaps (cpu util < 60%)");
    let path = write_output("fig5_sweep.csv", &csv).unwrap();
    println!("wrote {path}");
    println!("bench wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
