//! L3 hot-path microbenchmarks (criterion is not in the offline crate set;
//! this is a plain harness with warmup + repeated timed runs).
//!
//! Measures the coordinator's hot paths (DAG generation is timed first
//! and subtracted, so per-model rates denominate simulation time only):
//!   1. full 16k-task simulation wall time per model, reported as
//!      tasks/sec simulated, events/sec, and allocations/task (a counting
//!      global allocator wraps `System`)
//!   2. engine readiness propagation throughput
//!   3. PJRT artifact execution latency (if artifacts are built)
//!
//! Results are also written to `BENCH_driver.json` (crate root) so the
//! perf trajectory is tracked across PRs — see EXPERIMENTS.md
//! §"Performance methodology" for the schema and the recorded baselines.
//!
//!   cargo bench --bench coordinator_hotpath
//!
//! CI runs a reduced smoke config: `HF_BENCH_GRID=4 HF_BENCH_ITERS=1`.

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::engine::Engine;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::runtime::{Runtime, Tensor};
use hyperflow_k8s::util::env::{bench_threads, env_usize};
use hyperflow_k8s::util::json::Json;
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Counts heap allocations so the bench can report allocations/task — the
/// zero-alloc claim for the steady-state event loop is checked here, not
/// guessed (EXPERIMENTS.md §Perf).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs_now() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn timed<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    let iters = iters.max(1); // guard HF_BENCH_ITERS=0 -> division by zero
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:>44}: {:>10.3} ms/iter  ({iters} iters)", per * 1000.0);
    per
}

fn main() {
    println!("== coordinator hot paths ==\n");

    // reduced grid for CI smoke runs: HF_BENCH_GRID=4
    let grid = env_usize("HF_BENCH_GRID", 52);
    let wf = MontageConfig {
        grid_w: grid,
        grid_h: grid,
        diagonals: true,
        seed: 42,
    };
    let n = generate(&wf).len();
    println!("montage {grid}x{grid}: {n} tasks\n");

    // DAG generation is measured first so the per-model rates below can
    // subtract it — the timed closures must regenerate the DAG each
    // iteration (driver::run consumes it), but BENCH_driver.json tracks
    // the *simulator* trajectory, not the generator's.
    let per_gen = timed("montage generation", 10, || {
        std::hint::black_box(generate(&wf).len());
    });

    // 1. full simulation runs
    let mut model_rows: Vec<Json> = Vec::new();
    for (label, model) in [
        ("sim job-based", ExecModel::JobBased),
        (
            "sim clustered",
            ExecModel::Clustered(ClusteringConfig::paper_default()),
        ),
        ("sim worker-pools", ExecModel::paper_hybrid_pools()),
        ("sim generic-pool", ExecModel::GenericPool),
    ] {
        let m2 = model.clone();
        let default_iters = if matches!(m2, ExecModel::JobBased) { 3 } else { 10 };
        let iters = env_usize("HF_BENCH_ITERS", default_iters);
        // one instrumented run for events + allocation counts
        let dag = generate(&wf);
        let a0 = allocs_now();
        let res = driver::run(dag, m2.clone(), driver::SimConfig::with_nodes(17));
        let allocs_per_task = (allocs_now() - a0) as f64 / n as f64;
        let sim_events = res.sim_events;
        let arena = res.event_arena;
        std::hint::black_box(res.makespan);
        // timed runs; subtract the known generation cost so the recorded
        // rates denominate simulation time only (matching allocs_per_task,
        // which is also measured around driver::run alone)
        let per_total = timed(label, iters, || {
            let res = driver::run(
                generate(&wf),
                m2.clone(),
                driver::SimConfig::with_nodes(17),
            );
            std::hint::black_box(res.makespan);
        });
        let per = (per_total - per_gen).max(1e-9);
        let tasks_per_sec = n as f64 / per;
        let events_per_sec = sim_events as f64 / per;
        println!(
            "{:>44}  -> {:.0} tasks/sec, {:.0} events/sec, {:.1} allocs/task, \
             arena reuse {:.1}%",
            "",
            tasks_per_sec,
            events_per_sec,
            allocs_per_task,
            arena.reuse_ratio() * 100.0
        );
        model_rows.push(Json::obj(vec![
            ("model", Json::str(model.name())),
            ("ms_per_iter", (per * 1000.0).into()),
            ("tasks_per_sec", tasks_per_sec.into()),
            ("events_per_sec", events_per_sec.into()),
            ("sim_events", sim_events.into()),
            ("allocs_per_task", allocs_per_task.into()),
            ("event_arena_allocs", arena.allocs.into()),
            ("event_arena_reuses", arena.reuses.into()),
            ("event_arena_reuse_ratio", arena.reuse_ratio().into()),
        ]));
    }

    // 2. engine readiness propagation (generation cost subtracted, as for
    // the model rates — the closure must rebuild the consumed DAG)
    let per_engine = (timed("engine drain (readiness only)", 10, || {
        let (mut eng, mut ready) = Engine::new(generate(&wf));
        let mut buf: Vec<_> = Vec::new();
        while let Some(t) = ready.pop() {
            buf.clear();
            eng.complete_into(t, &mut buf);
            ready.append(&mut buf);
        }
        assert!(eng.is_done());
    }) - per_gen)
        .max(1e-9);

    // persist the trajectory (read by humans and the `diff --bench` gate)
    let out = Json::obj(vec![
        ("bench", Json::str("coordinator_hotpath")),
        ("schema_version", hyperflow_k8s::util::meta::BENCH_SCHEMA_VERSION.into()),
        (
            "meta",
            hyperflow_k8s::util::meta::bench_meta(
                "all-models",
                wf.seed,
                &driver::SimConfig::with_nodes(17).fingerprint(),
            ),
        ),
        ("grid", grid.into()),
        ("tasks", n.into()),
        // timing benches are serial; this records the harness knob so a
        // perf regression can be correlated with the thread setting (the
        // sweep benches are thread-invariant by construction and do not
        // record it — see EXPERIMENTS.md §"Raw speed")
        ("bench_threads", bench_threads().into()),
        ("models", Json::Arr(model_rows)),
        ("engine_drain_ms", (per_engine * 1000.0).into()),
        ("dag_generation_ms", (per_gen * 1000.0).into()),
    ]);
    let path = "BENCH_driver.json";
    match std::fs::write(path, out.to_string()) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => println!("\ncould not write {path}: {e}"),
    }

    // 3. PJRT execution latency (needs `make artifacts`)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = Runtime::load_subset("artifacts", &["mproject", "mdifffit"]).unwrap();
        let t = rt.manifest().tile;
        let v = rt.manifest().overlap;
        let img = Tensor::new(vec![0.5; t * t], &[t, t]);
        let params = Tensor::new(vec![1.0, 0.0, 0.0, 1.0, 0.3, -0.2], &[6]);
        timed("pjrt mproject (128x128 reproject)", 50, || {
            std::hint::black_box(rt.execute("mproject", &[img.clone(), params.clone()]).unwrap());
        });
        let p = Tensor::new(vec![0.25; t * v], &[t, v]);
        timed("pjrt mdifffit (128x32 moments+solve)", 50, || {
            std::hint::black_box(
                rt.execute("mdifffit", &[p.clone(), p.clone(), p.clone()])
                    .unwrap(),
            );
        });
    } else {
        println!("(artifacts not built: skipping PJRT latency benches)");
    }
}
