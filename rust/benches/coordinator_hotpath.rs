//! L3 hot-path microbenchmarks (criterion is not in the offline crate set;
//! this is a plain harness with warmup + repeated timed runs).
//!
//! Measures the coordinator's three hot paths:
//!   1. full 16k-task simulation wall time (events/sec) per model
//!   2. engine readiness propagation throughput
//!   3. PJRT artifact execution latency (if artifacts are built)
//!
//!   cargo bench --bench coordinator_hotpath

use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::engine::Engine;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::runtime::{Runtime, Tensor};
use hyperflow_k8s::workflow::montage::{generate, MontageConfig};
use std::time::Instant;

fn timed<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:>44}: {:>10.3} ms/iter  ({iters} iters)", per * 1000.0);
    per
}

fn main() {
    println!("== coordinator hot paths ==\n");

    // 1. full simulation runs
    let wf16k = MontageConfig::paper_16k();
    let n = generate(&wf16k).len();
    for (label, model) in [
        ("sim 16k job-based", ExecModel::JobBased),
        (
            "sim 16k clustered",
            ExecModel::Clustered(ClusteringConfig::paper_default()),
        ),
        ("sim 16k worker-pools", ExecModel::paper_hybrid_pools()),
    ] {
        let m2 = model.clone();
        let iters = if matches!(m2, ExecModel::JobBased) { 3 } else { 10 };
        let per = timed(label, iters, || {
            let res = driver::run(
                generate(&wf16k),
                m2.clone(),
                driver::SimConfig::with_nodes(17),
            );
            std::hint::black_box(res.makespan);
        });
        println!(
            "{:>44}  -> {:.0} tasks/sec simulated",
            "", n as f64 / per
        );
    }

    // 2. engine readiness propagation
    timed("engine drain 16k (readiness only)", 10, || {
        let (mut eng, mut ready) = Engine::new(generate(&wf16k));
        while let Some(t) = ready.pop() {
            let mut newly = eng.complete(t);
            ready.append(&mut newly);
        }
        assert!(eng.is_done());
    });

    // 3. DAG generation
    timed("montage 16k generation", 10, || {
        std::hint::black_box(generate(&wf16k).len());
    });

    // 4. PJRT execution latency (needs `make artifacts`)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = Runtime::load_subset("artifacts", &["mproject", "mdifffit"]).unwrap();
        let t = rt.manifest().tile;
        let v = rt.manifest().overlap;
        let img = Tensor::new(vec![0.5; t * t], &[t, t]);
        let params = Tensor::new(vec![1.0, 0.0, 0.0, 1.0, 0.3, -0.2], &[6]);
        timed("pjrt mproject (128x128 reproject)", 50, || {
            std::hint::black_box(rt.execute("mproject", &[img.clone(), params.clone()]).unwrap());
        });
        let p = Tensor::new(vec![0.25; t * v], &[t, v]);
        timed("pjrt mdifffit (128x32 moments+solve)", 50, || {
            std::hint::black_box(
                rt.execute("mdifffit", &[p.clone(), p.clone(), p.clone()])
                    .unwrap(),
            );
        });
    } else {
        println!("(artifacts not built: skipping PJRT latency benches)");
    }
}
