//! Tenant-takeover isolation sweep: run the multi-tenant fleet under a
//! deterministic tenant-compromise scenario (`takeover:0@T`) and measure,
//! per execution model per isolation policy, the compromised tenant's
//! blast radius and the collateral damage the *innocent* tenants suffer.
//! This demonstrates the isolation gradient the quotas/node-pool work is
//! for: `shared` lets the takeover cordon the whole cluster, `dedicated`
//! confines the drain to the victim's node partition, and `sandboxed`
//! contains the escape entirely (only the compromised tenant's own pods
//! are killed). See EXPERIMENTS.md §"Multi-tenancy / isolation" for how
//! to read the table.
//!
//! Results are written to `BENCH_isolation.json` (crate root, next to
//! `BENCH_chaos.json` and `BENCH_fleet.json`).
//!
//!   cargo bench --bench tenant_takeover
//!
//! CI runs a reduced grid: `HF_ISO_DURATION=1200 HF_ISO_RATE=12`.

use hyperflow_k8s::chaos::ChaosConfig;
use hyperflow_k8s::engine::clustering::ClusteringConfig;
use hyperflow_k8s::fleet::{self, ArrivalProcess, FleetConfig};
use hyperflow_k8s::k8s::isolation::IsolationConfig;
use hyperflow_k8s::models::{driver, ExecModel};
use hyperflow_k8s::util::env::{env_f64, env_usize};
use hyperflow_k8s::util::json::Json;
use hyperflow_k8s::util::sweep;

fn main() {
    let nodes = env_usize("HF_ISO_NODES", 12);
    let tenants = env_usize("HF_ISO_TENANTS", 3);
    let duration = env_f64("HF_ISO_DURATION", 3600.0);
    let rate = env_f64("HF_ISO_RATE", 24.0);
    let seed: u64 = 42;
    // the compromise lands mid-window, when every tenant has work in flight
    let takeover_s = duration / 2.0;

    let models: Vec<(&str, ExecModel)> = vec![
        ("job-based", ExecModel::JobBased),
        (
            "job-clustered",
            ExecModel::Clustered(ClusteringConfig::paper_default()),
        ),
        ("worker-pools", ExecModel::paper_hybrid_pools()),
        ("generic-pool", ExecModel::GenericPool),
    ];
    // policy sweep: same quota everywhere, only the node-pool policy moves
    let policies: Vec<(&str, String)> = vec![
        ("shared", "shared,quota:16000x65536".into()),
        ("dedicated", "dedicated,quota:16000x65536".into()),
        ("sandboxed", "sandboxed,quota:16000x65536".into()),
    ];

    let fleet_cfg = FleetConfig {
        arrival: ArrivalProcess::Poisson { per_hour: rate },
        duration_s: duration,
        tenants: fleet::default_tenants(tenants, &[3, 4]),
        seed,
        max_in_flight: None,
    };
    let mk_sim = |iso: Option<&str>, chaos: Option<&str>| {
        let mut cfg = driver::SimConfig::with_nodes(nodes);
        cfg.seed = seed;
        cfg.isolation =
            iso.map(|s| IsolationConfig::parse_spec(s).expect("bench isolation spec"));
        if let Some(spec) = chaos {
            cfg.chaos = ChaosConfig::parse_spec(spec).expect("bench chaos spec");
        }
        cfg
    };

    println!(
        "== tenant takeover sweep == ({nodes} nodes, {tenants} tenants, \
         {rate}/h over {duration:.0}s, takeover of tenant 0 at {takeover_s:.0}s, seed {seed})\n"
    );
    let chaos_spec = format!("takeover:0@{takeover_s}");
    // flatten the model x (baseline + policies) grid into independent
    // sweep points; each is a self-contained seeded fleet run, so the
    // fan-out leaves output and BENCH_isolation.json byte-identical to
    // the serial loop
    let mut grid_pts: Vec<(usize, Option<usize>)> = Vec::new();
    for m in 0..models.len() {
        grid_pts.push((m, None));
        for p in 0..policies.len() {
            grid_pts.push((m, Some(p)));
        }
    }
    let results = sweep::run(grid_pts, |_, (m, policy)| {
        let sim = match policy {
            None => mk_sim(None, None), // healthy baseline: isolation off
            Some(p) => mk_sim(Some(&policies[p].1), Some(&chaos_spec)),
        };
        let res = fleet::run(models[m].1.clone(), sim, &fleet_cfg);
        let agg = fleet::report::aggregate(&res);
        let rows = fleet::report::per_tenant(&res);
        (agg, rows, res.sim.isolation)
    });
    let stride = 1 + policies.len();
    let mut model_rows: Vec<Json> = Vec::new();
    for (m, (name, _)) in models.iter().enumerate() {
        let base_agg = &results[m * stride].0;
        println!(
            "{name}: healthy span {:.0}s, mean slowdown {:.2}",
            base_agg.span_s, base_agg.mean_slowdown
        );
        let mut points: Vec<Json> = Vec::new();
        for (p, (policy, iso_spec)) in policies.iter().enumerate() {
            let (agg, rows, iso) = &results[m * stride + 1 + p];
            let victim = &rows[0];
            let innocents: Vec<_> = rows.iter().skip(1).collect();
            let n_i = innocents.len().max(1) as f64;
            let innocent_slowdown =
                innocents.iter().map(|r| r.slowdown_mean).sum::<f64>() / n_i;
            let innocent_exposed_s =
                innocents.iter().map(|r| r.takeover_exposed_s).sum::<f64>();
            println!(
                "  {policy:>9}: victim slowdown {:>6.2}  innocent slowdown {:>6.2} \
                 (healthy {:>5.2})  blast {:>2} nodes / {:>3} pods ({:>3} innocent)  \
                 exposed {innocent_exposed_s:>7.1}s  throttles {:>4}  violations {:>3}",
                victim.slowdown_mean,
                innocent_slowdown,
                base_agg.mean_slowdown,
                iso.blast_nodes,
                iso.blast_pods,
                iso.blast_innocent_pods,
                iso.quota_throttles(),
                iso.violations(),
            );
            points.push(Json::obj(vec![
                ("policy", Json::str(policy)),
                ("isolation_spec", Json::str(iso_spec)),
                ("chaos_spec", Json::str(&chaos_spec)),
                ("span_s", agg.span_s.into()),
                ("utilization", agg.utilization.into()),
                ("victim_slowdown_mean", victim.slowdown_mean.into()),
                ("victim_slowdown_p99", victim.slowdown_p99.into()),
                ("innocent_slowdown_mean", innocent_slowdown.into()),
                ("innocent_takeover_exposed_s", innocent_exposed_s.into()),
                ("takeovers", iso.takeovers.into()),
                ("blast_nodes", iso.blast_nodes.into()),
                ("blast_pods", iso.blast_pods.into()),
                ("blast_innocent_pods", iso.blast_innocent_pods.into()),
                ("blast_storage_surfaces", iso.blast_storage_surfaces.into()),
                ("quota_throttles", iso.quota_throttles().into()),
                ("violations", iso.violations().into()),
            ]));
        }
        println!();
        model_rows.push(Json::obj(vec![
            ("model", Json::str(name)),
            ("healthy_span_s", base_agg.span_s.into()),
            ("healthy_mean_slowdown", base_agg.mean_slowdown.into()),
            ("points", Json::Arr(points)),
        ]));
    }

    let out = Json::obj(vec![
        ("bench", Json::str("tenant_takeover")),
        ("schema_version", hyperflow_k8s::util::meta::BENCH_SCHEMA_VERSION.into()),
        (
            "meta",
            hyperflow_k8s::util::meta::bench_meta(
                "all-models",
                seed,
                &mk_sim(None, None).fingerprint(),
            ),
        ),
        ("nodes", nodes.into()),
        ("tenants", tenants.into()),
        ("duration_s", duration.into()),
        ("arrival_rate_per_hour", rate.into()),
        ("takeover_s", takeover_s.into()),
        ("seed", seed.into()),
        ("models", Json::Arr(model_rows)),
    ]);
    let path = "BENCH_isolation.json";
    match std::fs::write(path, out.to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
