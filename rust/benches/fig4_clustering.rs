//! Regenerates Fig. 4: job model + task clustering (the paper's config) on
//! the 16k-task Montage. The run completes, utilization is much better than
//! Fig. 3, but back-off-synchronized gaps appear (the paper's ~100 s gap
//! around t≈750 s).
//!
//!   cargo bench --bench fig4_clustering
//!
//! Writes bench_out/fig4_utilization.csv and bench_out/fig4.json.

use hyperflow_k8s::report::{figures, write_output};
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let (res, _wf, text) = figures::fig4_clustering();
    println!("{text}");

    // locate the largest utilization dip *inside the parallel phase* (the
    // paper's ~100 s pause around t≈750 s, caused by synchronized back-off
    // wake-ups), separately from the inherent serial mConcatFit->mBgModel
    // bottleneck that follows the mDiffFit stage.
    let parallel_end = res
        .trace
        .records
        .iter()
        .filter(|r| res.trace.type_name(r) == "mDiffFit")
        .filter_map(|r| r.finished_at)
        .max()
        .map(|t| t.as_secs_f64())
        .unwrap_or(0.0);
    let series = res.running_series();
    let peak = series.iter().map(|&(_, v)| v).fold(0.0f64, f64::max);
    let mut dip = (0.0f64, 0.0f64, 0.0f64); // (len, start, end)
    let mut cur_start: Option<f64> = None;
    for &(t, v) in &series {
        if t > parallel_end {
            break;
        }
        if v < 0.3 * peak {
            cur_start.get_or_insert(t);
        } else if let Some(s) = cur_start.take() {
            if t - s > dip.0 && s > 30.0 {
                dip = (t - s, s, t);
            }
        }
    }
    println!(
        "largest low-utilization dip in the parallel phase: {:.0}s (t={:.0}s..{:.0}s)",
        dip.0, dip.1, dip.2
    );
    println!("  [paper Fig. 4: a ~100s gap around t≈750s from back-off-delayed mProject batches]");
    let csv = write_output("fig4_utilization.csv", &res.utilization_csv()).unwrap();
    let json = write_output("fig4.json", &res.to_json().to_string()).unwrap();
    println!("wrote {csv}, {json}");
    println!("bench wall time: {:.2}s", t0.elapsed().as_secs_f64());
}
