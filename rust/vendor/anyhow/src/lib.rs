//! Offline shim for `anyhow`.
//!
//! Provides the subset this workspace uses: [`Error`], [`Result`], the
//! [`anyhow!`]/[`bail!`] macros, and the [`Context`] extension trait with
//! `context`/`with_context`. Errors are flattened to strings — rich enough
//! for the CLI/runtime error paths here, and the published `anyhow` crate
//! is a drop-in replacement.

use std::fmt;

/// A string-backed error value with an optional context chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error {
            msg: msg.to_string(),
        }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` prints the full chain in real anyhow; the shim's chain is
        // already flattened, so both render the same string
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — the ubiquitous alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] when `cond` is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path/zz")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_wraps_messages() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| "outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let e2: Error = anyhow!("num {}", 7);
        assert_eq!(e2.to_string(), "num 7");
    }

    #[test]
    fn bail_returns_error() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {}", 1);
            }
            Ok(3)
        }
        assert_eq!(f(false).unwrap(), 3);
        assert_eq!(f(true).unwrap_err().to_string(), "flagged 1");
    }
}
