//! Compile-only stub of the xla-rs PJRT bindings.
//!
//! The build environment has neither crates.io access nor the
//! `xla_extension` shared library, so this stub provides the exact API
//! surface `hyperflow_k8s::runtime` uses and fails at *runtime* with a
//! clear message. Every caller is already gated on
//! `artifacts/manifest.json` existing (`make artifacts`), so the simulator,
//! tests, and benches never hit these paths in CI. Swap this crate for the
//! published xla-rs bindings in Cargo.toml to run the real numerics — the
//! call sites compile unchanged against either.

use std::fmt;
use std::path::Path;

/// Error type matching xla-rs's role in `?` conversions.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: xla_extension is not available in this build \
             (offline stub; link the real xla crate to execute artifacts)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("to_literal_sync"))
    }
}

/// Parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client (stub; the real one is `Rc`-based and not `Send`).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// Compiled executable (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<Literal>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("xla_extension is not available"));
    }
}
