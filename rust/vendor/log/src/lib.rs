//! Offline shim for the `log` logging facade.
//!
//! Implements exactly the API subset this workspace uses: the five level
//! macros, the [`Log`] trait, [`set_logger`]/[`set_max_level`], and the
//! `Level`/`LevelFilter` pair (including cross-type comparison). The build
//! environment has no crates.io access, so the facade is vendored; the
//! published `log` crate is a drop-in replacement.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of a single log record.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Maximum-verbosity filter (adds `Off` below `Error`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata attached to a record (level + target module path).
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record, borrowed for the duration of the `Log::log` call.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

/// Returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the maximum level that reaches the logger.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// The currently configured maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if let Some(logger) = LOGGER.get() {
        let record = Record {
            metadata: Metadata { level, target },
            args,
        };
        logger.log(&record);
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__log(lvl, module_path!(), format_args!($($arg)+));
        }
    }};
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_vs_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Warn);
        assert!(Level::Warn <= LevelFilter::Warn);
        assert!(!(Level::Info <= LevelFilter::Warn));
        assert!(Level::Trace <= LevelFilter::Trace);
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    #[test]
    fn max_level_round_trips() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Warn);
        assert_eq!(max_level(), LevelFilter::Warn);
    }
}
