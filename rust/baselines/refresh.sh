#!/bin/sh
# Refresh the committed perf baselines: run every bench harness at the
# reduced CI knobs and copy the BENCH_*.json outputs into baselines/.
# Run from this directory (or anywhere inside the repo).
set -eu

cd "$(dirname "$0")/.."

HF_BENCH_GRID=4 HF_BENCH_ITERS=1 cargo bench --bench coordinator_hotpath
HF_FLEET_DURATION=400 HF_FLEET_NODES=4 cargo bench --bench fleet_saturation
HF_CHAOS_GRID=4 HF_CHAOS_RATES=2,4,8 cargo bench --bench chaos_resilience
HF_DATA_GRID=4 HF_DATA_RATES=0.5,2 cargo bench --bench data_locality
HF_ISO_DURATION=1200 HF_ISO_RATE=12 HF_ISO_NODES=6 cargo bench --bench tenant_takeover

# Validate each artifact before installing it as a baseline: valid JSON,
# current schema, full provenance meta, and not a placeholder. A bench
# that emits a malformed document must fail the refresh, not poison the
# committed baselines.
for f in BENCH_driver.json BENCH_fleet.json BENCH_chaos.json BENCH_data.json BENCH_isolation.json; do
    if [ ! -f "$f" ]; then
        echo "refresh: $f was not emitted" >&2
        exit 1
    fi
    python3 - "$f" <<'EOF'
import json, sys
path = sys.argv[1]
doc = json.load(open(path))
assert not doc.get("placeholder"), f"{path}: bench emitted a placeholder"
assert doc.get("schema_version") == 1, f"{path}: schema_version != 1"
meta = doc.get("meta")
assert isinstance(meta, dict), f"{path}: missing meta block"
for key in ("model", "seed", "git", "config_fingerprint"):
    assert key in meta, f"{path}: meta lacks '{key}'"
print(f"{path}: schema ok")
EOF
    cp "$f" baselines/"$f"
done
echo "baselines refreshed — review the diff before committing"
echo "gate check: cargo run --release -- diff --bench baselines/BENCH_driver.json BENCH_driver.json --tolerance baselines/tolerances.json"
