"""AOT export sanity: artifacts are valid HLO text with the right interfaces."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build_artifacts(out, grids=[2])
    return out, manifest


def test_manifest_lists_all_artifacts(built):
    out, manifest = built
    names = set(manifest["artifacts"])
    assert names == {
        "mproject", "mdifffit", "mbackground",
        "mbgmodel_g2", "madd_g2", "mshrink_g2",
    }
    for meta in manifest["artifacts"].values():
        assert os.path.exists(os.path.join(out, meta["file"]))


def test_manifest_round_trips_as_json(built):
    out, _ = built
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    assert m["tile"] == model.TILE
    assert m["overlap"] == model.OVERLAP
    assert m["grids"] == [2]


def test_hlo_text_has_entry_computation(built):
    out, manifest = built
    for meta in manifest["artifacts"].values():
        with open(os.path.join(out, meta["file"])) as f:
            text = f.read()
        assert "ENTRY" in text, meta["file"]
        assert "HloModule" in text, meta["file"]


def test_no_mosaic_custom_calls(built):
    """interpret=True Pallas + CG solve must lower to plain HLO — a Mosaic
    or LAPACK custom-call would be unloadable by the CPU PJRT client."""
    out, manifest = built
    for meta in manifest["artifacts"].values():
        with open(os.path.join(out, meta["file"])) as f:
            text = f.read()
        assert "tpu_custom_call" not in text, meta["file"]
        assert "getrf" not in text, meta["file"]


def test_shapes_match_model_contract(built):
    _, manifest = built
    T, V = model.TILE, model.OVERLAP
    a = manifest["artifacts"]
    assert a["mproject"]["inputs"][0]["shape"] == [T, T]
    assert a["mproject"]["inputs"][1]["shape"] == [6]
    assert a["mdifffit"]["inputs"][0]["shape"] == [T, V]
    assert a["mdifffit"]["outputs"][0]["shape"] == [3]
    c2 = model.canvas_size(2)
    assert a["madd_g2"]["outputs"][0]["shape"] == [c2, c2]
    assert a["mbgmodel_g2"]["outputs"][0]["shape"] == [4]
    assert a["mshrink_g2"]["outputs"][0]["shape"] == [c2 // 4, c2 // 4]


def test_dtypes_are_declared(built):
    _, manifest = built
    for meta in manifest["artifacts"].values():
        for io in meta["inputs"] + meta["outputs"]:
            assert io["dtype"] in ("float32", "int32")
