"""L2 model correctness: Montage task-type graphs and the full pipeline."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

S, OV = model.TILE, model.OVERLAP
STEP = S - OV


def sky(yy, xx):
    """Deterministic smooth synthetic sky over global coordinates."""
    return (
        np.sin(xx / 37.0) + np.cos(yy / 29.0) + 0.002 * xx + 0.001 * yy
    ).astype(np.float32)


def make_grid(g, seed=42, identity=True):
    """Raw tiles on a g x g grid with per-tile constant background errors."""
    rng = np.random.default_rng(seed)
    raws, params, offs = [], [], []
    for i in range(g * g):
        r, c = divmod(i, g)
        yy, xx = np.meshgrid(
            np.arange(S) + r * STEP, np.arange(S) + c * STEP, indexing="ij"
        )
        off = float(rng.normal() * 2.0)
        raws.append(sky(yy, xx) + off)
        if identity:
            params.append(np.array([1, 0, 0, 1, 0, 0], np.float32))
        else:
            params.append(
                np.array(
                    [1 + rng.normal() * 0.002, rng.normal() * 0.002,
                     rng.normal() * 0.002, 1 + rng.normal() * 0.002,
                     rng.normal() * 0.25, rng.normal() * 0.25],
                    np.float32,
                )
            )
        offs.append(off)
    return raws, params, np.array(offs, np.float32)


# ---------------------------------------------------------------------------
# mdifffit: moments + 3x3 solve vs lstsq oracle
# ---------------------------------------------------------------------------
class TestMDiffFit:
    def test_recovers_known_plane(self):
        rng = np.random.default_rng(7)
        yy, xx = np.meshgrid(np.arange(S), np.arange(OV), indexing="ij")
        a, b, c = 1.5, 0.01, -0.02
        p2 = rng.normal(size=(S, OV)).astype(np.float32)
        p1 = p2 + (a + b * xx + c * yy).astype(np.float32)
        w = np.ones((S, OV), np.float32)
        coeffs = np.array(model.mdifffit(jnp.array(p1), jnp.array(p2), jnp.array(w)))
        np.testing.assert_allclose(coeffs, [a, b, c], rtol=1e-2, atol=1e-3)

    def test_matches_lstsq_oracle(self):
        rng = np.random.default_rng(11)
        p1 = rng.normal(size=(S, OV)).astype(np.float32)
        p2 = rng.normal(size=(S, OV)).astype(np.float32)
        w = (rng.random((S, OV)) > 0.3).astype(np.float32)
        got = np.array(model.mdifffit(jnp.array(p1), jnp.array(p2), jnp.array(w)))
        want = np.array(ref.plane_fit_ref(jnp.array(p1), jnp.array(p2), jnp.array(w)))
        np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-3)

    def test_degenerate_all_zero_mask(self):
        p1 = np.ones((S, OV), np.float32)
        p2 = np.zeros((S, OV), np.float32)
        w = np.zeros((S, OV), np.float32)
        coeffs = np.array(model.mdifffit(jnp.array(p1), jnp.array(p2), jnp.array(w)))
        assert np.all(np.isfinite(coeffs))
        np.testing.assert_allclose(coeffs, 0.0, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_plane_recovery(self, seed):
        rng = np.random.default_rng(seed)
        a = float(rng.normal() * 3)
        b = float(rng.normal() * 0.05)
        c = float(rng.normal() * 0.05)
        yy, xx = np.meshgrid(np.arange(S), np.arange(OV), indexing="ij")
        p2 = rng.normal(size=(S, OV)).astype(np.float32) * 0.01
        p1 = p2 + (a + b * xx + c * yy).astype(np.float32)
        w = np.ones((S, OV), np.float32)
        coeffs = np.array(model.mdifffit(jnp.array(p1), jnp.array(p2), jnp.array(w)))
        np.testing.assert_allclose(coeffs, [a, b, c], rtol=5e-2, atol=5e-3)


# ---------------------------------------------------------------------------
# mbgmodel: CG solve on the correction graph
# ---------------------------------------------------------------------------
class TestMBgModel:
    def _solve(self, g, offs):
        """Feed exact pairwise differences; expect mean-free offsets back."""
        edges = model.grid_edges(g)
        src = jnp.array([e[0] for e in edges], jnp.int32)
        dst = jnp.array([e[1] for e in edges], jnp.int32)
        d = jnp.array([offs[i] - offs[j] for i, j in edges], jnp.float32)
        ew = jnp.ones(len(edges), jnp.float32)
        return np.array(model.mbgmodel(src, dst, d, ew, n_images=g * g))

    @pytest.mark.parametrize("g", [2, 3, 4, 5])
    def test_exact_diffs_recover_offsets(self, g):
        rng = np.random.default_rng(g)
        offs = rng.normal(size=g * g).astype(np.float32) * 3
        got = self._solve(g, offs)
        want = offs - offs.mean()
        np.testing.assert_allclose(got, want, atol=5e-3)

    def test_mean_free(self):
        rng = np.random.default_rng(0)
        offs = rng.normal(size=16).astype(np.float32)
        got = self._solve(4, offs)
        assert abs(got.mean()) < 1e-4

    def test_zero_diffs_zero_offsets(self):
        got = self._solve(4, np.zeros(16, np.float32))
        np.testing.assert_allclose(got, 0.0, atol=1e-6)

    def test_noisy_diffs_least_squares(self):
        """With noisy edge measurements CG should still find the LS optimum:
        compare against dense numpy solve of the same regularized system."""
        g = 4
        n = g * g
        rng = np.random.default_rng(3)
        edges = model.grid_edges(g)
        d = rng.normal(size=len(edges)).astype(np.float32)
        lam = 1e-4
        A = np.eye(n) * lam
        b = np.zeros(n)
        for k, (i, j) in enumerate(edges):
            A[i, i] += 1; A[j, j] += 1; A[i, j] -= 1; A[j, i] -= 1
            b[i] += d[k]; b[j] -= d[k]
        want = np.linalg.solve(A, b)
        want -= want.mean()
        src = jnp.array([e[0] for e in edges], jnp.int32)
        dst = jnp.array([e[1] for e in edges], jnp.int32)
        got = np.array(
            model.mbgmodel(src, dst, jnp.array(d), jnp.ones(len(edges)), n_images=n)
        )
        np.testing.assert_allclose(got, want, atol=1e-3)

    def test_masked_edges_ignored(self):
        """Edges with weight 0 must not influence the solution."""
        g = 3
        n = g * g
        edges = model.grid_edges(g)
        rng = np.random.default_rng(5)
        offs = rng.normal(size=n).astype(np.float32)
        src = jnp.array([e[0] for e in edges], jnp.int32)
        dst = jnp.array([e[1] for e in edges], jnp.int32)
        d = np.array([offs[i] - offs[j] for i, j in edges], np.float32)
        ew = np.ones(len(edges), np.float32)
        # corrupt one edge but mask it out
        d2 = d.copy(); d2[0] = 1000.0
        ew2 = ew.copy(); ew2[0] = 0.0
        got = np.array(model.mbgmodel(src, dst, jnp.array(d2), jnp.array(ew2), n_images=n))
        want = offs - offs.mean()
        np.testing.assert_allclose(got, want, atol=2e-2)


# ---------------------------------------------------------------------------
# mbackground / madd / mshrink
# ---------------------------------------------------------------------------
class TestAssembly:
    def test_mbackground_subtracts_only_where_data(self):
        rng = np.random.default_rng(9)
        img = rng.normal(size=(S, S)).astype(np.float32)
        w = np.zeros((S, S), np.float32)
        w[:64] = 1.0
        out = np.array(model.mbackground(jnp.array(img), jnp.array(w), jnp.array([2.0], jnp.float32)))
        np.testing.assert_allclose(out[:64], img[:64] - 2.0, rtol=1e-6)
        np.testing.assert_allclose(out[64:], img[64:], rtol=1e-6)

    def test_madd_single_tile(self):
        img = np.random.default_rng(1).normal(size=(1, S, S)).astype(np.float32)
        w = np.ones((1, S, S), np.float32)
        acc, wacc, norm = model.madd(
            jnp.array(img), jnp.array(w), jnp.array([0]), jnp.array([0]),
            canvas_hw=(S, S),
        )
        np.testing.assert_allclose(np.array(norm), img[0], rtol=1e-6)
        np.testing.assert_allclose(np.array(wacc), 1.0)

    def test_madd_overlap_averages(self):
        a = np.full((S, S), 2.0, np.float32)
        b = np.full((S, S), 4.0, np.float32)
        imgs = np.stack([a, b])
        ws = np.ones((2, S, S), np.float32)
        H = S + STEP
        acc, wacc, norm = model.madd(
            jnp.array(imgs), jnp.array(ws),
            jnp.array([0, STEP]), jnp.array([0, 0]), canvas_hw=(H, S),
        )
        norm = np.array(norm)
        np.testing.assert_allclose(norm[:STEP], 2.0)            # only a
        np.testing.assert_allclose(norm[STEP:S], 3.0)           # overlap avg
        np.testing.assert_allclose(norm[S:], 4.0)               # only b

    def test_madd_respects_weights(self):
        imgs = np.stack([np.full((S, S), 6.0, np.float32)])
        ws = np.full((1, S, S), 0.0, np.float32)
        _, _, norm = model.madd(
            jnp.array(imgs), jnp.array(ws), jnp.array([0]), jnp.array([0]),
            canvas_hw=(S, S),
        )
        np.testing.assert_allclose(np.array(norm), 0.0)

    def test_mshrink_block_mean(self):
        m = np.arange(16, dtype=np.float32).reshape(4, 4)
        out = np.array(model.mshrink(jnp.array(m), factor=2))
        want = np.array([[m[:2, :2].mean(), m[:2, 2:].mean()],
                         [m[2:, :2].mean(), m[2:, 2:].mean()]], np.float32)
        np.testing.assert_allclose(out, want)

    def test_canvas_size(self):
        assert model.canvas_size(1) == S
        assert model.canvas_size(4) == 3 * STEP + S

    def test_grid_edges_count(self):
        for g in range(1, 6):
            assert len(model.grid_edges(g)) == 2 * g * (g - 1)


# ---------------------------------------------------------------------------
# full pipeline
# ---------------------------------------------------------------------------
class TestPipeline:
    @pytest.mark.parametrize("g", [2, 3])
    def test_recovers_sky_up_to_dc(self, g):
        raws, params, offs = make_grid(g)
        norm, offsets = model.pipeline_reference(
            [jnp.array(r) for r in raws], [jnp.array(p) for p in params], g
        )
        # offsets are recovered mean-free
        want = offs - offs.mean()
        np.testing.assert_allclose(np.array(offsets), want, atol=5e-3)
        # mosaic equals true sky up to a global DC (the unobservable gauge)
        cs = model.canvas_size(g)
        yy, xx = np.meshgrid(np.arange(cs), np.arange(cs), indexing="ij")
        true = sky(yy, xx)
        mos = np.array(norm)
        resid = (mos - true)[:-1, :-1]  # exclude uncovered border
        resid -= resid.mean()
        assert np.abs(resid).max() < 1e-2

    def test_nonidentity_projection_still_converges(self):
        g = 2
        raws, params, offs = make_grid(g, identity=False)
        norm, offsets = model.pipeline_reference(
            [jnp.array(r) for r in raws], [jnp.array(p) for p in params], g
        )
        # with small warps the offsets should still be close
        want = offs - offs.mean()
        np.testing.assert_allclose(np.array(offsets), want, atol=0.1)
        assert np.all(np.isfinite(np.array(norm)))
