"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Includes hypothesis sweeps over shapes/contents — the Pallas grids must
produce identical numerics to the vectorized references for any divisible
blocking.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.coadd import coadd_normalize
from compile.kernels.difffit import difffit_moments
from compile.kernels.reproject import reproject

RNG = np.random.default_rng(1234)


def rand_img(h, w):
    return RNG.normal(size=(h, w)).astype(np.float32)


# ---------------------------------------------------------------------------
# reproject
# ---------------------------------------------------------------------------
class TestReproject:
    @pytest.mark.parametrize(
        "params",
        [
            [1, 0, 0, 1, 0, 0],            # identity
            [1, 0, 0, 1, 0.5, -0.3],        # subpixel shift
            [0.999, 0.01, -0.01, 1.001, 0.5, -0.3],  # small shear
            [0.995, 0.05, -0.05, 0.995, 2.0, 1.0],   # rotation-ish
            [1, 0, 0, 1, 200.0, 0.0],       # fully out of range -> weight 0
        ],
        ids=["identity", "shift", "shear", "rot", "oob"],
    )
    def test_matches_ref(self, params):
        img = rand_img(128, 128)
        p = np.array(params, np.float32)
        out, w = reproject(jnp.array(img), jnp.array(p))
        out_r, w_r = ref.reproject_ref(jnp.array(img), jnp.array(p))
        np.testing.assert_allclose(np.array(out), np.array(out_r), rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.array(w), np.array(w_r))

    def test_identity_is_exact_inside(self):
        img = rand_img(128, 128)
        p = np.array([1, 0, 0, 1, 0, 0], np.float32)
        out, w = reproject(jnp.array(img), jnp.array(p))
        np.testing.assert_allclose(np.array(out)[:127, :127], img[:127, :127], atol=1e-6)
        # last row/col: bilinear footprint leaves the image -> weight 0
        assert float(np.array(w)[127].sum()) == 0.0
        assert float(np.array(w)[:, 127].sum()) == 0.0

    def test_weight_is_binary(self):
        img = rand_img(128, 128)
        p = np.array([1.01, 0.02, -0.01, 0.99, -3.0, 5.0], np.float32)
        _, w = reproject(jnp.array(img), jnp.array(p))
        vals = np.unique(np.array(w))
        assert set(vals.tolist()) <= {0.0, 1.0}

    def test_zero_weight_pixels_are_zero(self):
        img = rand_img(128, 128) + 10.0
        p = np.array([1, 0, 0, 1, 100.0, 100.0], np.float32)
        out, w = reproject(jnp.array(img), jnp.array(p))
        out = np.array(out)
        w = np.array(w)
        assert np.all(out[w == 0.0] == 0.0)

    @pytest.mark.parametrize("block_rows", [16, 32, 64, 128])
    def test_blocking_invariance(self, block_rows):
        img = rand_img(128, 128)
        p = np.array([0.99, 0.03, -0.02, 1.01, 1.5, -2.5], np.float32)
        out, w = reproject(jnp.array(img), jnp.array(p), block_rows=block_rows)
        out_r, w_r = ref.reproject_ref(jnp.array(img), jnp.array(p))
        # tolerance: XLA fuses the lerp differently per blocking (f32 FMA)
        np.testing.assert_allclose(np.array(out), np.array(out_r), rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.array(w), np.array(w_r))

    def test_indivisible_block_raises(self):
        img = rand_img(128, 128)
        p = jnp.zeros(6)
        with pytest.raises(ValueError):
            reproject(jnp.array(img), p, block_rows=48)

    @settings(max_examples=20, deadline=None)
    @given(
        h=st.sampled_from([32, 64, 96, 128]),
        w=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, h, w, seed):
        r = np.random.default_rng(seed)
        img = r.normal(size=(h, w)).astype(np.float32)
        p = np.array(
            [1 + r.normal() * 0.01, r.normal() * 0.01, r.normal() * 0.01,
             1 + r.normal() * 0.01, r.normal(), r.normal()],
            np.float32,
        )
        out, wgt = reproject(jnp.array(img), jnp.array(p), block_rows=h // 2)
        out_r, w_r = ref.reproject_ref(jnp.array(img), jnp.array(p))
        np.testing.assert_allclose(np.array(out), np.array(out_r), rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.array(wgt), np.array(w_r))


# ---------------------------------------------------------------------------
# difffit moments
# ---------------------------------------------------------------------------
class TestDifffitMoments:
    def test_matches_ref(self):
        p1, p2 = rand_img(128, 32), rand_img(128, 32)
        w = (RNG.random((128, 32)) > 0.25).astype(np.float32)
        m = difffit_moments(jnp.array(p1), jnp.array(p2), jnp.array(w))
        m_r = ref.difffit_moments_ref(jnp.array(p1), jnp.array(p2), jnp.array(w))
        np.testing.assert_allclose(np.array(m), np.array(m_r), rtol=1e-4)

    def test_zero_mask_gives_zero_moments(self):
        p1, p2 = rand_img(128, 32), rand_img(128, 32)
        w = np.zeros((128, 32), np.float32)
        m = np.array(difffit_moments(jnp.array(p1), jnp.array(p2), jnp.array(w)))
        np.testing.assert_array_equal(m, np.zeros(9, np.float32))

    def test_count_moment(self):
        p1, p2 = rand_img(128, 32), rand_img(128, 32)
        w = np.ones((128, 32), np.float32)
        m = np.array(difffit_moments(jnp.array(p1), jnp.array(p2), jnp.array(w)))
        assert m[0] == 128 * 32

    def test_identical_patches_zero_d_moments(self):
        p1 = rand_img(128, 32)
        w = np.ones((128, 32), np.float32)
        m = np.array(difffit_moments(jnp.array(p1), jnp.array(p1), jnp.array(w)))
        np.testing.assert_allclose(m[6:], 0.0, atol=1e-3)

    @pytest.mark.parametrize("block_rows", [8, 16, 32, 64, 128])
    def test_blocking_invariance(self, block_rows):
        p1, p2 = rand_img(128, 32), rand_img(128, 32)
        w = (RNG.random((128, 32)) > 0.5).astype(np.float32)
        m = difffit_moments(jnp.array(p1), jnp.array(p2), jnp.array(w), block_rows=block_rows)
        m_r = ref.difffit_moments_ref(jnp.array(p1), jnp.array(p2), jnp.array(w))
        np.testing.assert_allclose(np.array(m), np.array(m_r), rtol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        h=st.sampled_from([32, 64, 128, 256]),
        w=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, h, w, seed):
        r = np.random.default_rng(seed)
        p1 = r.normal(size=(h, w)).astype(np.float32)
        p2 = r.normal(size=(h, w)).astype(np.float32)
        msk = (r.random((h, w)) > 0.3).astype(np.float32)
        m = difffit_moments(jnp.array(p1), jnp.array(p2), jnp.array(msk), block_rows=h // 4)
        m_r = ref.difffit_moments_ref(jnp.array(p1), jnp.array(p2), jnp.array(msk))
        np.testing.assert_allclose(np.array(m), np.array(m_r), rtol=5e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# coadd normalize
# ---------------------------------------------------------------------------
class TestCoaddNormalize:
    def test_matches_ref(self):
        acc = rand_img(416, 416)
        wacc = (RNG.random((416, 416)) * 3).astype(np.float32)
        out = coadd_normalize(jnp.array(acc), jnp.array(wacc))
        out_r = ref.coadd_normalize_ref(jnp.array(acc), jnp.array(wacc))
        np.testing.assert_allclose(np.array(out), np.array(out_r), rtol=1e-6)

    def test_zero_weight_gives_zero(self):
        acc = rand_img(64, 64)
        wacc = np.zeros((64, 64), np.float32)
        out = np.array(coadd_normalize(jnp.array(acc), jnp.array(wacc)))
        np.testing.assert_array_equal(out, np.zeros_like(out))

    def test_weight_two_halves(self):
        acc = np.full((64, 64), 8.0, np.float32)
        wacc = np.full((64, 64), 2.0, np.float32)
        out = np.array(coadd_normalize(jnp.array(acc), jnp.array(wacc)))
        np.testing.assert_allclose(out, 4.0)

    def test_non_divisible_height_falls_back(self):
        # 52 not divisible by 32 -> single-block fallback path
        acc = rand_img(52, 64)
        wacc = np.ones((52, 64), np.float32)
        out = coadd_normalize(jnp.array(acc), jnp.array(wacc))
        np.testing.assert_allclose(np.array(out), acc, rtol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        h=st.sampled_from([32, 64, 100, 416]),
        w=st.sampled_from([32, 64, 416]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, h, w, seed):
        r = np.random.default_rng(seed)
        acc = r.normal(size=(h, w)).astype(np.float32)
        wacc = np.where(r.random((h, w)) > 0.4, r.random((h, w)) * 3, 0).astype(np.float32)
        out = coadd_normalize(jnp.array(acc), jnp.array(wacc))
        out_r = ref.coadd_normalize_ref(jnp.array(acc), jnp.array(wacc))
        np.testing.assert_allclose(np.array(out), np.array(out_r), rtol=1e-5, atol=1e-6)
