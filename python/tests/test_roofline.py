"""Sanity checks for the structural roofline estimator."""

from compile import roofline


def test_all_kernels_fit_vmem():
    for k in roofline.report():
        assert k.vmem_fraction < 0.10, f"{k.name} uses {k.vmem_fraction:.0%} of VMEM"


def test_kernels_are_memory_bound():
    # no matmuls in the Montage hot path: everything should sit left of the
    # ridge point
    for k in roofline.report():
        assert k.bound == "memory", k.name


def test_estimates_positive_and_scale():
    small = roofline.reproject(64, 32)
    large = roofline.reproject(256, 32)
    assert 0 < small.est_time_us < large.est_time_us
    assert large.hbm_bytes > small.hbm_bytes


def test_block_rows_tradeoff():
    # larger blocks raise VMEM residency but never past the budget for our
    # shapes
    k32 = roofline.reproject(128, 32)
    k128 = roofline.reproject(128, 128)
    assert k128.vmem_per_block > k32.vmem_per_block
    assert k128.vmem_fraction < 0.10
