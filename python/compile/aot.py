"""AOT export: lower each Montage task type to HLO text for the Rust runtime.

Emits HLO *text* (NOT lowered.compiler_ir().as_hlo_module() protos nor
``.serialize()``): jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the published `xla` 0.1.6 crate
links) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Artifacts (for grid g, tile T=128, overlap V=32, N=g*g, E=2g(g-1),
C=(g-1)*(T-V)+T):

  mproject.hlo.txt     (img[T,T] f32, params[6] f32) -> (proj[T,T], weight[T,T])
  mdifffit.hlo.txt     (p1[T,V], p2[T,V], w[T,V])    -> (coeffs[3],)
  mbgmodel_g{g}.hlo.txt(src[E] i32, dst[E] i32, d[E] f32, ew[E] f32) -> (off[N],)
  mbackground.hlo.txt  (img[T,T], w[T,T], offset[1]) -> (corrected[T,T],)
  madd_g{g}.hlo.txt    (imgs[N,T,T], ws[N,T,T], oy[N] i32, ox[N] i32)
                       -> (acc[C,C], wacc[C,C], mosaic[C,C])
  mshrink_g{g}.hlo.txt (mosaic[C,C]) -> (small[C/4,C/4],)

plus manifest.json describing every artifact's shapes (read by
rust/src/runtime/manifest.rs).

Usage: python -m compile.aot --out-dir ../artifacts [--grid 4 [--grid 3]]
"""

import argparse
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _tupled(fn):
    """Ensure the lowered function returns a tuple (rust side expects it)."""

    def wrapped(*args):
        out = fn(*args)
        return out if isinstance(out, tuple) else (out,)

    return wrapped


def build_artifacts(out_dir: str, grids: list[int]) -> dict:
    T, V = model.TILE, model.OVERLAP
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "tile": T,
        "overlap": V,
        "grids": grids,
        "artifacts": {},
    }

    def emit(name, fn, specs, outputs):
        lowered = jax.jit(_tupled(fn)).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "outputs": outputs,
        }
        print(f"  {name}: {len(text)} chars")

    # Grid-independent task types
    emit(
        "mproject",
        model.mproject,
        [spec((T, T)), spec((6,))],
        [{"shape": [T, T], "dtype": "float32"}] * 2,
    )
    emit(
        "mdifffit",
        model.mdifffit,
        [spec((T, V)), spec((T, V)), spec((T, V))],
        [{"shape": [3], "dtype": "float32"}],
    )
    emit(
        "mbackground",
        model.mbackground,
        [spec((T, T)), spec((T, T)), spec((1,))],
        [{"shape": [T, T], "dtype": "float32"}],
    )

    # Grid-dependent task types
    for g in grids:
        n = g * g
        e = 2 * g * (g - 1)
        c = model.canvas_size(g)
        emit(
            f"mbgmodel_g{g}",
            partial(model.mbgmodel, n_images=n),
            [spec((e,), I32), spec((e,), I32), spec((e,)), spec((e,))],
            [{"shape": [n], "dtype": "float32"}],
        )
        emit(
            f"madd_g{g}",
            partial(model.madd, canvas_hw=(c, c)),
            [spec((n, T, T)), spec((n, T, T)), spec((n,), I32), spec((n,), I32)],
            [{"shape": [c, c], "dtype": "float32"}] * 3,
        )
        emit(
            f"mshrink_g{g}",
            model.mshrink,
            [spec((c, c))],
            [{"shape": [c // 4, c // 4], "dtype": "float32"}],
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--grid", type=int, action="append", default=None,
        help="tile-grid sizes to build grid-dependent artifacts for",
    )
    args = ap.parse_args()
    grids = args.grid or [4]
    print(f"AOT-lowering Montage task types (grids={grids}) -> {args.out_dir}")
    build_artifacts(args.out_dir, grids)
    print("done")


if __name__ == "__main__":
    main()
