"""L2: JAX compute graphs for the Montage task types (build-time only).

Each public function here is the *numeric payload* of one Montage task
type. They call the L1 Pallas kernels (kernels/*.py) so that everything
lowers into a single HLO module per task type; `aot.py` exports them as
HLO text which the Rust runtime (rust/src/runtime) loads and executes via
PJRT. Python never runs on the request path.

Geometry convention (shared with rust/src/compute/, see manifest.json):
  * every input tile is TILE x TILE pixels (default 128);
  * tiles sit on a g x g grid with OVERLAP-pixel overlap between
    4-neighbours;
  * the per-image background error is a constant offset per tile
    (Montage fits planes; we fit the full plane in mDiffFit but correct
    the constant term — the simplification is documented in DESIGN.md).
"""

import jax
import jax.numpy as jnp

from compile.kernels.coadd import coadd_normalize
from compile.kernels.difffit import difffit_moments
from compile.kernels.reproject import reproject

TILE = 128
OVERLAP = 32


# ---------------------------------------------------------------------------
# mProject: reproject one raw tile onto the canonical grid.
# ---------------------------------------------------------------------------
def mproject(img, params):
    """(TILE,TILE) raw image + affine params (6,) -> (projected, weight)."""
    return reproject(img, params)


# ---------------------------------------------------------------------------
# mDiffFit: plane fit to the difference of two projected tiles over their
# overlap patch. Kernel accumulates moments; 3x3 normal-equation solve here.
# ---------------------------------------------------------------------------
def mdifffit(p1, p2, w):
    """Overlap patches (H,W) -> plane coefficients (a, b, c) as (3,)."""
    m = difffit_moments(p1, p2, w)
    n, sx, sy, sxx, sxy, syy, sd, sdx, sdy = (m[i] for i in range(9))
    # Normal equations for d ~ a + b*x + c*y, Tikhonov-regularized so the
    # solve stays well-posed for degenerate masks (e.g. all-zero overlap).
    eps = 1e-6
    a00, a01, a02 = n + eps, sx, sy
    a10, a11, a12 = sx, sxx + eps, sxy
    a20, a21, a22 = sy, sxy, syy + eps
    det = (
        a00 * (a11 * a22 - a12 * a21)
        - a01 * (a10 * a22 - a12 * a20)
        + a02 * (a10 * a21 - a11 * a20)
    )
    det = jnp.where(jnp.abs(det) < 1e-12, 1e-12, det)
    # 3x3 solve by explicit adjugate (avoids LAPACK custom-calls in HLO).
    inv = (
        jnp.array(
            [
                [a11 * a22 - a12 * a21, a02 * a21 - a01 * a22, a01 * a12 - a02 * a11],
                [a12 * a20 - a10 * a22, a00 * a22 - a02 * a20, a02 * a10 - a00 * a12],
                [a10 * a21 - a11 * a20, a01 * a20 - a00 * a21, a00 * a11 - a01 * a10],
            ]
        )
        / det
    )
    rhs = jnp.stack([sd, sdx, sdy])
    return inv @ rhs


# ---------------------------------------------------------------------------
# mBgModel: global background correction. Solve the graph least-squares
#   min_x sum_e ew_e * (x[src_e] - x[dst_e] - d_e)^2 + lam * ||x||^2
# by conjugate gradient with a fixed iteration count (pure HLO: no LAPACK).
# ---------------------------------------------------------------------------
def mbgmodel(src, dst, d, ew, *, n_images: int, iters: int | None = None):
    """Edge list -> per-image offsets (n_images,), mean-free."""
    lam = jnp.float32(1e-4)
    iters = iters if iters is not None else 4 * n_images

    def matvec(x):
        t = ew * (x[src] - x[dst])
        y = jnp.zeros(n_images, jnp.float32).at[src].add(t).at[dst].add(-t)
        return y + lam * x

    b = jnp.zeros(n_images, jnp.float32).at[src].add(ew * d).at[dst].add(-ew * d)

    def cg_step(state, _):
        x, r, p, rs = state
        Ap = matvec(p)
        denom = jnp.dot(p, Ap)
        alpha = rs / jnp.where(jnp.abs(denom) < 1e-20, 1e-20, denom)
        x = x + alpha * p
        r = r - alpha * Ap
        rs_new = jnp.dot(r, r)
        beta = rs_new / jnp.where(rs < 1e-20, 1e-20, rs)
        p = r + beta * p
        return (x, r, p, rs_new), None

    x0 = jnp.zeros(n_images, jnp.float32)
    state = (x0, b, b, jnp.dot(b, b))
    (x, _, _, _), _ = jax.lax.scan(cg_step, state, None, length=iters)
    return x - jnp.mean(x)


# ---------------------------------------------------------------------------
# mBackground: subtract the fitted constant background from one tile.
# ---------------------------------------------------------------------------
def mbackground(img, w, offset):
    """Corrected tile: img - offset wherever the tile has data."""
    return img - offset[0] * (w > 0.0).astype(jnp.float32)


# ---------------------------------------------------------------------------
# mAdd: coadd corrected tiles into the mosaic canvas, then normalize
# (normalization is the L1 coadd kernel).
# ---------------------------------------------------------------------------
def madd(imgs, ws, oy, ox, *, canvas_hw):
    """Stack (N,TILE,TILE) + weights + per-tile origins -> mosaic triple.

    Returns (flux_acc, weight_acc, normalized_mosaic), each canvas-sized.
    Origins are runtime inputs (dynamic_update_slice), so one artifact
    serves any placement of N tiles.
    """
    n, th, tw = imgs.shape
    H, W = canvas_hw

    def body(i, accs):
        acc, wacc = accs
        o = (oy[i], ox[i])
        cur = jax.lax.dynamic_slice(acc, o, (th, tw))
        curw = jax.lax.dynamic_slice(wacc, o, (th, tw))
        acc = jax.lax.dynamic_update_slice(acc, cur + imgs[i] * ws[i], o)
        wacc = jax.lax.dynamic_update_slice(wacc, curw + ws[i], o)
        return (acc, wacc)

    acc = jnp.zeros((H, W), jnp.float32)
    wacc = jnp.zeros((H, W), jnp.float32)
    acc, wacc = jax.lax.fori_loop(0, n, body, (acc, wacc))
    norm = coadd_normalize(acc, wacc)
    return acc, wacc, norm


# ---------------------------------------------------------------------------
# mShrink: block-average downsample of the mosaic (for preview/mJPEG).
# ---------------------------------------------------------------------------
def mshrink(mosaic, *, factor: int = 4):
    h, w = mosaic.shape
    hh, ww = h // factor, w // factor
    m = mosaic[: hh * factor, : ww * factor]
    return m.reshape(hh, factor, ww, factor).mean(axis=(1, 3))


# ---------------------------------------------------------------------------
# Grid geometry helpers shared by aot.py, the tests, and (by convention)
# rust/src/compute/.
# ---------------------------------------------------------------------------
def grid_edges(g: int):
    """4-neighbourhood edges of a g x g tile grid (right + down)."""
    edges = []
    for r in range(g):
        for c in range(g):
            i = r * g + c
            if c + 1 < g:
                edges.append((i, r * g + c + 1))
            if r + 1 < g:
                edges.append((i, (r + 1) * g + c))
    return edges


def canvas_size(g: int, tile: int = TILE, overlap: int = OVERLAP):
    step = tile - overlap
    return (g - 1) * step + tile


def pipeline_reference(raws, params, g: int):
    """Run mProject -> mDiffFit -> mBgModel -> mBackground -> mAdd in pure
    JAX over a g x g grid of raw tiles. Returns the normalized mosaic and
    the recovered offsets. Used by the python tests and mirrored by the
    Rust e2e example."""
    n = g * g
    projs, wgts = [], []
    for i in range(n):
        p, w = mproject(raws[i], params[i])
        projs.append(p)
        wgts.append(w)

    step = TILE - OVERLAP
    edges = grid_edges(g)
    ds = []
    for (i, j) in edges:
        ri, ci = divmod(i, g)
        rj, cj = divmod(j, g)
        if cj == ci + 1:  # horizontal neighbour: right OVERLAP strip of i
            p1 = projs[i][:, step:]
            p2 = projs[j][:, :OVERLAP]
            w12 = wgts[i][:, step:] * wgts[j][:, :OVERLAP]
        else:  # vertical neighbour: bottom strip of i vs top of j, transposed
            p1 = projs[i][step:, :].T
            p2 = projs[j][:OVERLAP, :].T
            w12 = (wgts[i][step:, :] * wgts[j][:OVERLAP, :]).T
        coeffs = mdifffit(p1, p2, w12)
        ds.append(coeffs[0])  # constant term drives the bg model

    src = jnp.array([e[0] for e in edges], jnp.int32)
    dst = jnp.array([e[1] for e in edges], jnp.int32)
    d = jnp.stack(ds)
    ew = jnp.ones(len(edges), jnp.float32)
    offsets = mbgmodel(src, dst, d, ew, n_images=n)

    corrected = [
        mbackground(projs[i], wgts[i], offsets[i : i + 1]) for i in range(n)
    ]
    cs = canvas_size(g)
    oy = jnp.array([divmod(i, g)[0] * step for i in range(n)], jnp.int32)
    ox = jnp.array([divmod(i, g)[1] * step for i in range(n)], jnp.int32)
    _, _, norm = madd(
        jnp.stack(corrected),
        jnp.stack(wgts),
        oy,
        ox,
        canvas_hw=(cs, cs),
    )
    return norm, offsets
