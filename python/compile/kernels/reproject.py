"""L1 Pallas kernel: bilinear inverse-warp reprojection (the mProject hot loop).

Montage's mProject resamples an input FITS image onto a canonical output
grid. Our synthetic equivalent inverse-warps the input image with an
affine transform and bilinearly interpolates; output pixels whose sample
footprint falls outside the input get weight 0.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the kernel is tiled over
*output row blocks* — each program instance holds one (BLOCK_ROWS, W)
output tile plus the full (H, W) input in VMEM (128x128 f32 = 64 KiB,
far below the ~16 MiB VMEM budget), computes the warped sample
coordinates with the VPU, and performs the 4-neighbour gather + lerp.
Lowered with interpret=True for CPU PJRT execution.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 32


def _reproject_kernel(img_ref, params_ref, out_ref, w_ref, *, block_rows: int):
    """One output row-block: inverse-warp + bilinear gather.

    params = [a11, a12, a21, a22, tx, ty]:
      xs = a11*j + a12*i + tx,  ys = a21*j + a22*i + ty
    where (i, j) are *global* output pixel coordinates.
    """
    img = img_ref[...]
    p = params_ref[...]
    h, w = img.shape

    row0 = pl.program_id(0) * block_rows
    ii = row0 + jax.lax.broadcasted_iota(jnp.float32, (block_rows, w), 0)
    jj = jax.lax.broadcasted_iota(jnp.float32, (block_rows, w), 1)

    xs = p[0] * jj + p[1] * ii + p[4]
    ys = p[2] * jj + p[3] * ii + p[5]

    x0 = jnp.floor(xs)
    y0 = jnp.floor(ys)
    fx = xs - x0
    fy = ys - y0
    x0i = x0.astype(jnp.int32)
    y0i = y0.astype(jnp.int32)

    valid = (x0i >= 0) & (x0i + 1 <= w - 1) & (y0i >= 0) & (y0i + 1 <= h - 1)
    x0c = jnp.clip(x0i, 0, w - 2)
    y0c = jnp.clip(y0i, 0, h - 2)

    flat = img.reshape(-1)
    base = y0c * w + x0c
    v00 = jnp.take(flat, base)
    v01 = jnp.take(flat, base + 1)
    v10 = jnp.take(flat, base + w)
    v11 = jnp.take(flat, base + w + 1)

    top = v00 * (1.0 - fx) + v01 * fx
    bot = v10 * (1.0 - fx) + v11 * fx
    val = top * (1.0 - fy) + bot * fy

    wgt = valid.astype(jnp.float32)
    out_ref[...] = val * wgt
    w_ref[...] = wgt


@partial(jax.jit, static_argnames=("block_rows",))
def reproject(img, params, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Inverse-warp `img` (H, W) by affine `params` (6,).

    Returns (projected, weight), both (H, W) float32. Weight is 1 where the
    bilinear footprint was fully inside the input, else 0 (and the
    projected value is zeroed there).
    """
    h, w = img.shape
    if h % block_rows != 0:
        raise ValueError(f"H={h} not divisible by block_rows={block_rows}")
    grid = (h // block_rows,)
    return pl.pallas_call(
        partial(_reproject_kernel, block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((h, w), lambda i: (0, 0)),      # full input image
            pl.BlockSpec((6,), lambda i: (0,)),           # affine params
        ],
        out_specs=[
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, w), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, w), jnp.float32),
            jax.ShapeDtypeStruct((h, w), jnp.float32),
        ],
        interpret=True,
    )(img.astype(jnp.float32), params.astype(jnp.float32))
