"""L1 Pallas kernel: overlap-difference moment accumulation (mDiffFit hot loop).

Montage's mDiffFit fits a plane a + b*x + c*y to the difference of two
reprojected images over their overlap region, by least squares. The hot
loop is a single pass over the overlap pixels accumulating the 9 moments
of the normal equations:

    n   = sum(w)          sx  = sum(w*x)        sy  = sum(w*y)
    sxx = sum(w*x*x)      sxy = sum(w*x*y)      syy = sum(w*y*y)
    sd  = sum(w*d)        sdx = sum(w*d*x)      sdy = sum(w*d*y)

with d = p1 - p2 and w the joint validity mask. The 3x3 solve happens at
L2 (model.mdifffit) — the kernel is the O(H*W) part.

TPU mapping: a reduction kernel tiled over row blocks; each program
instance reduces its (BLOCK_ROWS, W) tile to a 9-vector in VMEM scratch
and accumulates into the (9,) output across sequential grid steps
(Pallas grids execute sequentially on a TPU core, so the read-modify-write
accumulation is race-free). interpret=True for CPU PJRT.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 32


def _difffit_kernel(p1_ref, p2_ref, w_ref, out_ref, *, block_rows: int):
    p1 = p1_ref[...]
    p2 = p2_ref[...]
    w = w_ref[...]
    h = block_rows
    _, wd = p1.shape

    row0 = pl.program_id(0) * block_rows
    yy = row0 + jax.lax.broadcasted_iota(jnp.float32, (h, wd), 0)
    xx = jax.lax.broadcasted_iota(jnp.float32, (h, wd), 1)

    d = p1 - p2
    moments = jnp.stack(
        [
            jnp.sum(w),
            jnp.sum(w * xx),
            jnp.sum(w * yy),
            jnp.sum(w * xx * xx),
            jnp.sum(w * xx * yy),
            jnp.sum(w * yy * yy),
            jnp.sum(w * d),
            jnp.sum(w * d * xx),
            jnp.sum(w * d * yy),
        ]
    )

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += moments


@partial(jax.jit, static_argnames=("block_rows",))
def difffit_moments(p1, p2, w, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Accumulate the 9 plane-fit moments over an overlap patch.

    p1, p2: (H, W) overlap patches of the two projected images.
    w: (H, W) joint validity mask (0/1).
    Returns moments (9,) float32 in the order documented above.
    """
    h, wd = p1.shape
    if h % block_rows != 0:
        raise ValueError(f"H={h} not divisible by block_rows={block_rows}")
    grid = (h // block_rows,)
    return pl.pallas_call(
        partial(_difffit_kernel, block_rows=block_rows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, wd), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, wd), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, wd), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((9,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((9,), jnp.float32),
        interpret=True,
    )(p1.astype(jnp.float32), p2.astype(jnp.float32), w.astype(jnp.float32))
