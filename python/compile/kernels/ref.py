"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite checks the kernels against
(assert_allclose). They are deliberately written in the most obvious
vectorized-numpy style, independent of the kernels' tiling.
"""

import jax.numpy as jnp


def reproject_ref(img, params):
    """Reference bilinear inverse-warp. Mirrors kernels.reproject."""
    img = img.astype(jnp.float32)
    p = params.astype(jnp.float32)
    h, w = img.shape
    ii, jj = jnp.meshgrid(
        jnp.arange(h, dtype=jnp.float32),
        jnp.arange(w, dtype=jnp.float32),
        indexing="ij",
    )
    xs = p[0] * jj + p[1] * ii + p[4]
    ys = p[2] * jj + p[3] * ii + p[5]
    x0 = jnp.floor(xs)
    y0 = jnp.floor(ys)
    fx = xs - x0
    fy = ys - y0
    x0i = x0.astype(jnp.int32)
    y0i = y0.astype(jnp.int32)
    valid = (x0i >= 0) & (x0i + 1 <= w - 1) & (y0i >= 0) & (y0i + 1 <= h - 1)
    x0c = jnp.clip(x0i, 0, w - 2)
    y0c = jnp.clip(y0i, 0, h - 2)
    v00 = img[y0c, x0c]
    v01 = img[y0c, x0c + 1]
    v10 = img[y0c + 1, x0c]
    v11 = img[y0c + 1, x0c + 1]
    top = v00 * (1.0 - fx) + v01 * fx
    bot = v10 * (1.0 - fx) + v11 * fx
    val = top * (1.0 - fy) + bot * fy
    wgt = valid.astype(jnp.float32)
    return val * wgt, wgt


def difffit_moments_ref(p1, p2, w):
    """Reference 9-moment accumulation. Mirrors kernels.difffit."""
    p1 = p1.astype(jnp.float32)
    p2 = p2.astype(jnp.float32)
    w = w.astype(jnp.float32)
    h, wd = p1.shape
    yy, xx = jnp.meshgrid(
        jnp.arange(h, dtype=jnp.float32),
        jnp.arange(wd, dtype=jnp.float32),
        indexing="ij",
    )
    d = p1 - p2
    return jnp.stack(
        [
            jnp.sum(w),
            jnp.sum(w * xx),
            jnp.sum(w * yy),
            jnp.sum(w * xx * xx),
            jnp.sum(w * xx * yy),
            jnp.sum(w * yy * yy),
            jnp.sum(w * d),
            jnp.sum(w * d * xx),
            jnp.sum(w * d * yy),
        ]
    )


def coadd_normalize_ref(acc, wacc):
    """Reference weighted-coadd normalization. Mirrors kernels.coadd."""
    acc = acc.astype(jnp.float32)
    wacc = wacc.astype(jnp.float32)
    return jnp.where(wacc > 0.0, acc / jnp.maximum(wacc, 1.0), 0.0)


def plane_fit_ref(p1, p2, w):
    """End-to-end plane fit a + b*x + c*y to (p1 - p2) by lstsq (oracle for
    model.mdifffit: moments kernel + 3x3 solve)."""
    h, wd = p1.shape
    yy, xx = jnp.meshgrid(
        jnp.arange(h, dtype=jnp.float32),
        jnp.arange(wd, dtype=jnp.float32),
        indexing="ij",
    )
    mask = (w.reshape(-1) > 0).astype(jnp.float32)
    A = jnp.stack([jnp.ones(h * wd), xx.reshape(-1), yy.reshape(-1)], axis=1)
    d = (p1 - p2).astype(jnp.float32).reshape(-1)
    Aw = A * mask[:, None]
    dw = d * mask
    coeffs, *_ = jnp.linalg.lstsq(Aw, dw, rcond=None)
    return coeffs
