"""L1 Pallas kernel: weighted coaddition normalize step (mAdd hot loop).

mAdd coadds the background-corrected tiles into the mosaic canvas:
accumulation happens at L2 via dynamic_update_slice (origins are runtime
inputs); the per-pixel hot loop — normalizing the accumulated flux by the
accumulated weight with a zero-weight guard — is this kernel.

TPU mapping: purely element-wise VPU work, tiled over row blocks of the
(typically larger-than-tile) canvas. interpret=True for CPU PJRT.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 32


def _coadd_norm_kernel(acc_ref, wacc_ref, out_ref):
    acc = acc_ref[...]
    wacc = wacc_ref[...]
    safe = jnp.maximum(wacc, 1.0)
    out_ref[...] = jnp.where(wacc > 0.0, acc / safe, 0.0)


@partial(jax.jit, static_argnames=("block_rows",))
def coadd_normalize(acc, wacc, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Normalize accumulated flux by accumulated weight.

    acc, wacc: (H, W) float32 accumulators. Returns (H, W) float32 mosaic
    with pixels of zero weight set to 0.
    """
    h, w = acc.shape
    br = block_rows if h % block_rows == 0 else h
    grid = (h // br,)
    return pl.pallas_call(
        _coadd_norm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, w), lambda i: (i, 0)),
            pl.BlockSpec((br, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(acc.astype(jnp.float32), wacc.astype(jnp.float32))
