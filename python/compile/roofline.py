"""TPU roofline/VMEM estimator for the Pallas kernels (DESIGN.md §Perf).

interpret=True wallclock is CPU-numpy time, NOT a TPU proxy, so L1
performance is assessed structurally: per-block VMEM footprint, bytes
moved HBM<->VMEM, FLOPs, and the resulting arithmetic intensity vs. the
TPU ridge point. All three Montage kernels are element-wise/reduction
(VPU) work with no matmul, so the bound is memory bandwidth; the job of
the BlockSpec is to keep blocks comfortably inside VMEM while maximizing
contiguous streaming.

Usage: python -m compile.roofline [--tile 128] [--block-rows 32]
"""

import argparse
from dataclasses import dataclass

VMEM_BYTES = 16 * 2 ** 20          # ~16 MiB per TensorCore
HBM_GBPS = 1200.0                  # v4-ish HBM bandwidth
VPU_GFLOPS = 4.0 * 8 * 128 * 940   # 8x128 VPU lanes * ~940MHz * 4 ops


@dataclass
class KernelEstimate:
    name: str
    vmem_per_block: int            # bytes resident per program instance
    hbm_bytes: int                 # total unique bytes in+out per call
    flops: int                     # floating ops per call
    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1)
    @property
    def bound(self) -> str:
        ridge = VPU_GFLOPS / HBM_GBPS  # flops per byte at the ridge
        return "memory" if self.arithmetic_intensity < ridge else "compute"
    @property
    def vmem_fraction(self) -> float:
        return self.vmem_per_block / VMEM_BYTES
    @property
    def est_time_us(self) -> float:
        """Roofline time: max(bandwidth time, compute time)."""
        bw = self.hbm_bytes / (HBM_GBPS * 1e9)
        fl = self.flops / (VPU_GFLOPS * 1e9)
        return max(bw, fl) * 1e6


def reproject(tile: int, block_rows: int) -> KernelEstimate:
    f = 4  # f32
    # per block: full input image + params + out/weight blocks + coord temps
    vmem = tile * tile * f + 6 * f + 2 * block_rows * tile * f + 6 * block_rows * tile * f
    hbm = tile * tile * f + 6 * f + 2 * tile * tile * f
    # ~30 flops per output pixel (coords, lerp, mask)
    flops = 30 * tile * tile
    return KernelEstimate("reproject", vmem, hbm, flops)


def difffit(tile: int, overlap: int, block_rows: int) -> KernelEstimate:
    f = 4
    vmem = 3 * block_rows * overlap * f + 9 * f + 4 * block_rows * overlap * f
    hbm = 3 * tile * overlap * f + 9 * f
    flops = 25 * tile * overlap  # 9 masked reductions sharing temporaries
    return KernelEstimate("difffit", vmem, hbm, flops)


def coadd(canvas: int, block_rows: int) -> KernelEstimate:
    f = 4
    vmem = 3 * block_rows * canvas * f
    hbm = 3 * canvas * canvas * f
    flops = 3 * canvas * canvas
    return KernelEstimate("coadd_normalize", vmem, hbm, flops)


def report(tile: int = 128, overlap: int = 32, block_rows: int = 32,
           canvas: int = 416) -> list[KernelEstimate]:
    return [
        reproject(tile, block_rows),
        difffit(tile, overlap, block_rows),
        coadd(canvas, block_rows),
    ]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--overlap", type=int, default=32)
    ap.add_argument("--block-rows", type=int, default=32)
    ap.add_argument("--canvas", type=int, default=416)
    a = ap.parse_args()
    print(f"{'kernel':>16} {'VMEM/blk':>10} {'%VMEM':>7} {'HBM B':>10} "
          f"{'AI f/B':>7} {'bound':>7} {'roofline us':>12}")
    for k in report(a.tile, a.overlap, a.block_rows, a.canvas):
        print(f"{k.name:>16} {k.vmem_per_block:>10} {k.vmem_fraction*100:>6.2f}% "
              f"{k.hbm_bytes:>10} {k.arithmetic_intensity:>7.2f} {k.bound:>7} "
              f"{k.est_time_us:>12.2f}")
    print("\nAll kernels are memory-bound VPU work: the BlockSpecs stream row")
    print("blocks (<1% of VMEM) so real-TPU efficiency ~= HBM bandwidth bound.")


if __name__ == "__main__":
    main()
